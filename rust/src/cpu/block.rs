//! The basic-block execution engine: predecode straight-line instruction
//! runs once, then dispatch whole blocks — one interrupt check, one fetch
//! translation and one stats/device-countdown update per *block* instead
//! of per instruction.
//!
//! A block is a maximal straight-line run starting at some physical
//! address: it ends at the first [`crate::isa::Op::ends_block`] instruction (branch/
//! jump, CSR/system, fence, WFI, trap), at a page boundary (one fetch
//! translation must cover every instruction), or at [`MAX_BLOCK_INSTS`].
//! The ender is *included* as the final instruction, so every block holds
//! at least one instruction and dispatch always makes progress.
//!
//! Bit-exactness with the per-tick engine rests on one invariant — the
//! interrupt-delivery inputs (`mip`/`mie`/`mstatus`/`vsstatus`/delegation)
//! are constant inside a block:
//!
//! - device-driven `mip` lines change only at a device-timebase update,
//!   and the dispatcher clamps block length to `device_countdown`, so a
//!   block never spans one (MMIO stores to CLINT/PLIC change *device*
//!   state, which reaches `csr.mip` only at that update — same as the
//!   per-tick engine);
//! - software changes them only via CSR/system instructions, which end
//!   blocks;
//! - trap entry changes them too, but an exception terminates block
//!   execution on the spot.
//!
//! Hence checking interrupts once per dispatch is *exactly* the per-tick
//! `CheckInterrupts()` cadence: every tick on which the answer could
//! differ from the previous tick starts a new dispatch. DESIGN.md §19
//! states the invariant; `tests/block_engine.rs` proves it differentially.
//!
//! Cached blocks are keyed by (physical address, privilege, V, TLB
//! generation). Three things can invalidate a block, matching the three
//! ways code changes underneath us:
//!
//! 1. **Guest stores to predecoded pages** (self-modifying code): the bus
//!    keeps a per-page code bitmap ([`crate::mem::code`]); a hit bumps
//!    `Bus::code_seq`, which the execution loop re-checks after every
//!    instruction (intra-block) and the dispatcher drains before every
//!    lookup (cross-block).
//! 2. **TLB flushes and flushless world switches**: the existing
//!    generation bump makes every cached block unreachable (the
//!    generation is part of the key), which also guarantees two guests'
//!    identical physical addresses can never alias each other's blocks
//!    across a world switch.
//! 3. **Fork / VMID rebind / checkpoint restore**: the block cache is
//!    derived state — bus clones reset the code tracker, bulk RAM writes
//!    queue a flush-everything sentinel, and restore calls
//!    [`Core::reset_derived`]. Nothing is ever serialized into CK3.

use crate::isa::{decode, Inst};
use crate::mem::{Bus, CODE_DIRTY_ALL, RAM_BASE};

use super::execute::{execute, fetch_translate};
use super::trap;
use super::{Core, StepEvent};

/// Upper bound on instructions per block. Longer straight-line runs are
/// split; execution already chunks at the device period
/// ([`crate::sim::TIME_DIVIDER`] = 100 ticks), so 128 covers a full
/// period with headroom while bounding per-slot memory.
pub const MAX_BLOCK_INSTS: usize = 128;

/// Direct-mapped slot count (power of two).
const BLOCK_SLOTS: usize = 2048;

/// One predecoded straight-line run.
struct CachedBlock {
    /// Physical address of the first instruction.
    pa: u64,
    prv: u8,
    virt: bool,
    /// TLB generation at build time; any flush or generation bump orphans
    /// the block (lookups compare against the live generation).
    gen: u64,
    insts: Vec<Inst>,
}

/// Direct-mapped cache of predecoded blocks. Lives in [`Core`] (one per
/// machine, like the decode cache); guests never own one, so forks have
/// nothing to clone.
pub struct BlockCache {
    slots: Vec<Option<Box<CachedBlock>>>,
    /// Last drained `Bus::code_seq` (see [`Core::drain_code_invalidations`]).
    seq_seen: u64,
    /// Blocks predecoded (cache misses).
    pub builds: u64,
    /// Dispatches served from the cache.
    pub hits: u64,
    /// Blocks dropped by code-page invalidation.
    pub invalidated: u64,
}

/// Counter snapshot of a [`BlockCache`] — what `SimStats::dump` prints
/// and `Machine::finish_telemetry` folds into the counter registry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub builds: u64,
    pub hits: u64,
    pub invalidated: u64,
}

impl BlockCache {
    /// Snapshot the dispatch counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats { builds: self.builds, hits: self.hits, invalidated: self.invalidated }
    }

    pub fn new() -> BlockCache {
        let mut slots = Vec::with_capacity(BLOCK_SLOTS);
        slots.resize_with(BLOCK_SLOTS, || None);
        BlockCache { slots, seq_seen: 0, builds: 0, hits: 0, invalidated: 0 }
    }

    #[inline]
    fn slot_of(pa: u64) -> usize {
        ((pa >> 2) ^ (pa >> 13)) as usize & (BLOCK_SLOTS - 1)
    }

    /// Drop every cached block (bulk invalidation / checkpoint restore).
    pub fn clear(&mut self) {
        for s in &mut self.slots {
            if s.take().is_some() {
                self.invalidated += 1;
            }
        }
    }

    /// Drop blocks predecoded from the given RAM page (index relative to
    /// `RAM_BASE`). O(slots), paid only on an actual self-modifying-code
    /// event.
    fn invalidate_ram_page(&mut self, page: u32) {
        for s in &mut self.slots {
            let stale = s
                .as_ref()
                .is_some_and(|b| ((b.pa - RAM_BASE) >> 12) as u32 == page);
            if stale {
                *s = None;
                self.invalidated += 1;
            }
        }
    }
}

impl Default for BlockCache {
    fn default() -> Self {
        BlockCache::new()
    }
}

impl Core {
    /// Apply the bus's queued code-page invalidations to the block cache.
    /// [`run_block`] calls it after translating and before every lookup;
    /// a no-op (one u64 compare) unless a store actually hit a predecoded
    /// page since the last drain.
    #[inline]
    pub(crate) fn drain_code_invalidations(&mut self, bus: &mut Bus) {
        if bus.code_seq() == self.block_cache.seq_seen {
            return;
        }
        self.block_cache.seq_seen = bus.code_seq();
        for page in bus.take_code_dirty() {
            if page == CODE_DIRTY_ALL {
                self.block_cache.clear();
            } else {
                self.block_cache.invalidate_ram_page(page);
            }
        }
    }
}

/// Outcome of one block dispatch.
pub struct BlockRun {
    /// Ticks consumed: retired instructions plus a trailing exception
    /// tick, when one ended the block.
    pub executed: u64,
    /// Instructions retired.
    pub retired: u64,
    /// Terminal event: `Retired` for a clean (or clamped) run,
    /// `Exception(..)` when the block ended in a delivered trap. The
    /// caller folds it into the stats and the `VmExit` mapping.
    pub event: StepEvent,
}

/// Execute up to `max_insts` instructions of the basic block at the
/// current PC. Returns `None` when the fast lane cannot run — misaligned
/// PC, faulting fetch translation, or a non-RAM (MMIO) fetch — and the
/// caller must fall back to one per-tick step, which raises any pending
/// fetch fault with exact per-tick semantics.
///
/// Preconditions (owned by [`crate::sim::Machine::block_step`]): the
/// device timebase is fresh (`device_countdown > 0`), the hart is not
/// parked in WFI, no interrupt is deliverable, and `max_insts >= 1`.
pub fn run_block(core: &mut Core, bus: &mut Bus, max_insts: u64) -> Option<BlockRun> {
    debug_assert!(max_insts >= 1, "block dispatch needs a tick of budget");
    let pc = core.hart.pc;
    if pc & 3 != 0 {
        return None;
    }
    let Ok(pa) = fetch_translate(core, bus, pc) else {
        return None;
    };
    if !bus.in_ram(pa, 4) {
        return None;
    }
    // The production walker never writes RAM during translation (Svade
    // semantics: missing A/D bits fault instead of being set in
    // hardware), but guard the invariant anyway: if a translation ever
    // does dirty a predecoded page — e.g. a future hardware-A/D walker
    // whose PTE pages share a page with code — drain before the lookup
    // below could serve the stale block. One u64 compare when idle.
    core.drain_code_invalidations(bus);

    let prv = core.hart.prv.bits() as u8;
    let virt = core.hart.virt;
    let gen = core.tlb.generation();
    let idx = BlockCache::slot_of(pa);
    let hit = core.block_cache.slots[idx]
        .as_ref()
        .is_some_and(|b| b.pa == pa && b.prv == prv && b.virt == virt && b.gen == gen);
    if hit {
        core.block_cache.hits += 1;
    } else {
        let insts = build_block(bus, pa);
        bus.note_code_page(pa);
        core.block_cache.builds += 1;
        core.block_cache.slots[idx] = Some(Box::new(CachedBlock { pa, prv, virt, gen, insts }));
    }

    // Take the block out of its slot so `execute` can borrow the core
    // mutably; put it back below (the pre-lookup drain removes it next
    // dispatch if an invalidation landed meanwhile).
    let blk = core.block_cache.slots[idx].take().expect("slot filled above");
    let seq0 = bus.code_seq();
    let mut executed = 0u64;
    let mut retired = 0u64;
    let mut event = StepEvent::Retired;
    for inst in blk.insts.iter() {
        if executed >= max_insts {
            break;
        }
        if let Some(t) = &mut core.trace {
            t.push(core.hart.pc, crate::trace::KIND_FETCH);
        }
        match execute(core, bus, inst) {
            Ok(next_pc) => {
                core.hart.pc = next_pc;
                core.hart.csr.minstret = core.hart.csr.minstret.wrapping_add(1);
                executed += 1;
                retired += 1;
                // A store may have latched SYSCON poweroff or patched a
                // predecoded code page; both must end the dispatch before
                // the next (possibly stale) instruction runs — exactly
                // where the per-tick engine would re-fetch.
                if bus.poweroff.is_some() || bus.code_seq() != seq0 {
                    break;
                }
            }
            Err(e) => {
                let target = trap::take_exception(&mut core.hart, &e);
                executed += 1;
                event = StepEvent::Exception(e.cause, target);
                break;
            }
        }
    }
    core.block_cache.slots[idx] = Some(blk);
    Some(BlockRun { executed, retired, event })
}

/// Predecode the block starting at physical address `pa` (known to be in
/// RAM). Decodes each word exactly once per build — the raw-bits decode
/// cache stays dedicated to the per-tick engine.
fn build_block(bus: &Bus, pa: u64) -> Vec<Inst> {
    let mut insts = Vec::with_capacity(16);
    let mut at = pa;
    loop {
        let inst = decode(bus.read_ram(at, 4) as u32);
        let terminal = inst.op.ends_block();
        insts.push(inst);
        at += 4;
        if terminal
            || insts.len() >= MAX_BLOCK_INSTS
            || at & 0xfff == 0
            || !bus.in_ram(at, 4)
        {
            break;
        }
    }
    insts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::PAGE_SIZE;

    fn world() -> (Core, Bus) {
        let mut core = Core::new(true);
        core.hart.pc = RAM_BASE;
        (core, Bus::new(4 << 20))
    }

    fn addi(rd: u32, rs1: u32, imm: i32) -> u32 {
        ((imm as u32 & 0xfff) << 20) | (rs1 << 15) | (rd << 7) | 0b0010011
    }

    const JAL_SELF: u32 = 0b1101111; // jal x0, 0

    fn load_words(bus: &mut Bus, at: u64, words: &[u32]) {
        let mut bytes = Vec::new();
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bus.load_image(at, &bytes).unwrap();
    }

    #[test]
    fn build_stops_at_enders_page_edges_and_cap() {
        let (_, mut bus) = world();
        // addi, addi, jal — the jump is included as the terminal inst.
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), addi(6, 6, 2), JAL_SELF, addi(7, 7, 3)]);
        let b = build_block(&bus, RAM_BASE);
        assert_eq!(b.len(), 3);
        assert!(b[2].op.ends_block());

        // A straight-line run up to the page edge stops there.
        let edge = RAM_BASE + PAGE_SIZE as u64 - 8;
        load_words(&mut bus, edge, &[addi(5, 5, 1), addi(5, 5, 1), addi(5, 5, 1)]);
        let b = build_block(&bus, edge);
        assert_eq!(b.len(), 2, "block must not cross its fetch page");

        // An endless straight line hits the cap.
        let run = vec![addi(5, 5, 1); MAX_BLOCK_INSTS + 9];
        load_words(&mut bus, RAM_BASE + 2 * PAGE_SIZE as u64, &run);
        let b = build_block(&bus, RAM_BASE + 2 * PAGE_SIZE as u64);
        assert_eq!(b.len(), MAX_BLOCK_INSTS);
    }

    #[test]
    fn run_block_executes_and_caches() {
        let (mut core, mut bus) = world();
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), addi(6, 5, 2), JAL_SELF]);
        let r = run_block(&mut core, &mut bus, 100).expect("fast lane runs");
        assert_eq!(r.retired, 3);
        assert_eq!(r.executed, 3);
        assert_eq!(r.event, StepEvent::Retired);
        assert_eq!(core.hart.regs[5], 1);
        assert_eq!(core.hart.regs[6], 3);
        assert_eq!(core.hart.pc, RAM_BASE + 8, "jal x0,0 lands on itself");
        assert_eq!(core.block_cache.builds, 1);
        // Second dispatch at the jal target builds its own block; the
        // original start address stays cached.
        core.hart.pc = RAM_BASE;
        let r = run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(r.retired, 3);
        assert_eq!(core.block_cache.hits, 1);
    }

    #[test]
    fn clamp_stops_mid_block_and_resumes() {
        let (mut core, mut bus) = world();
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), addi(5, 5, 1), addi(5, 5, 1), JAL_SELF]);
        let r = run_block(&mut core, &mut bus, 2).unwrap();
        assert_eq!(r.retired, 2);
        assert_eq!(core.hart.pc, RAM_BASE + 8, "clamped mid-block");
        // Resuming mid-block builds a block at the new offset.
        let r = run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(r.retired, 2);
        assert_eq!(core.hart.regs[5], 3);
        assert_eq!(core.block_cache.builds, 2);
    }

    #[test]
    fn generation_bump_orphans_cached_blocks() {
        let (mut core, mut bus) = world();
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), JAL_SELF]);
        core.hart.pc = RAM_BASE;
        run_block(&mut core, &mut bus, 100).unwrap();
        core.hart.pc = RAM_BASE;
        core.tlb.bump_generation(); // flushless world switch
        run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(core.block_cache.builds, 2, "stale generation must rebuild");
        assert_eq!(core.block_cache.hits, 0);
    }

    #[test]
    fn store_into_cached_page_invalidates_blocks() {
        let (mut core, mut bus) = world();
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), JAL_SELF]);
        core.hart.pc = RAM_BASE;
        run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(bus.code_pages_marked(), 1);

        // Patch the first instruction: addi x5, x5, 1 -> addi x5, x5, 7.
        let seq0 = bus.code_seq();
        bus.write(RAM_BASE, 4, addi(5, 5, 7) as u64).unwrap();
        assert_eq!(bus.code_seq(), seq0 + 1);
        core.drain_code_invalidations(&mut bus);
        assert!(core.block_cache.invalidated > 0);

        core.hart.pc = RAM_BASE;
        run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(core.hart.regs[5], 1 + 7, "patched bytes must execute");
        assert_eq!(core.block_cache.builds, 2, "rebuilt after the patch");
    }

    #[test]
    fn exception_mid_block_ends_execution_with_correct_pc() {
        let (mut core, mut bus) = world();
        // addi; ld from unmapped physical space (fault); addi (must not run).
        let bad_ld = (0 << 20) | (7 << 15) | (0b011 << 12) | (6 << 7) | 0b0000011; // ld x6, 0(x7)
        core.hart.regs[7] = 0x10; // below every device: access fault
        load_words(&mut bus, RAM_BASE, &[addi(5, 5, 1), bad_ld, addi(5, 5, 100), JAL_SELF]);
        let r = run_block(&mut core, &mut bus, 100).unwrap();
        assert_eq!(r.retired, 1);
        assert_eq!(r.executed, 2, "the faulting instruction consumes its tick");
        assert!(matches!(r.event, StepEvent::Exception(..)));
        assert_eq!(core.hart.regs[5], 1, "nothing after the fault ran");
        assert_eq!(core.hart.csr.mepc, RAM_BASE + 4, "trap PC is the faulting inst");
    }

    #[test]
    fn fast_lane_declines_misaligned_and_mmio_pcs() {
        let (mut core, mut bus) = world();
        core.hart.pc = RAM_BASE + 2;
        assert!(run_block(&mut core, &mut bus, 10).is_none(), "misaligned");
        core.hart.pc = crate::mem::UART_BASE;
        assert!(run_block(&mut core, &mut bus, 10).is_none(), "MMIO fetch");
    }
}
