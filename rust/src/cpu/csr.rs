//! The CSR file: backing storage, read/write masks, aliases and VS-mode
//! redirection — the implementation of the paper's §3.1 and Table 1.
//!
//! Like gem5, several architecturally-distinct CSRs are *views* of the same
//! hardware register (sstatus ⊂ mstatus; sip/sie ⊂ mip/mie; hvip/hip ⊂ mip;
//! vsip/vsie are shifted views of the VS bits of mip/mie). The paper extends
//! gem5's READ masks with WRITE masks so read-only fields survive writes; we
//! implement both mask families here. In VS-mode, accesses to supervisor
//! CSRs are redirected to the `vs*` bank (Table 1, last row).

use crate::isa::csr::*;
use crate::isa::PrivLevel;

/// Why a CSR access failed — maps to IllegalInst or VirtualInstruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CsrError {
    Illegal,
    /// H-extension: access from VS/VU that must raise a
    /// virtual-instruction exception (cause 22).
    Virtual,
}

/// Writable field mask for mstatus (M-mode view).
const MSTATUS_WMASK: u64 = mstatus::SIE
    | mstatus::MIE
    | mstatus::SPIE
    | mstatus::MPIE
    | mstatus::SPP
    | mstatus::MPP_MASK
    | mstatus::FS_MASK
    | mstatus::MPRV
    | mstatus::SUM
    | mstatus::MXR
    | mstatus::TVM
    | mstatus::TW
    | mstatus::TSR
    | mstatus::MPV
    | mstatus::GVA;

/// sstatus view of mstatus: fields visible/writable from (H)S-mode.
const SSTATUS_MASK: u64 =
    mstatus::SIE | mstatus::SPIE | mstatus::SPP | mstatus::FS_MASK | mstatus::SUM | mstatus::MXR;

const HSTATUS_WMASK: u64 = hstatus::GVA
    | hstatus::SPV
    | hstatus::SPVP
    | hstatus::HU
    | hstatus::VGEIN_MASK
    | hstatus::VTVM
    | hstatus::VTW
    | hstatus::VTSR;

/// hedeleg writable bits: standard exceptions that may be delegated to VS.
/// Ecalls from HS/VS/M (9,10,11) and the guest-page-fault / virtual-inst
/// family (20–23) are hardwired to zero — those are always handled at HS or
/// above (privileged spec; paper §3.2).
const HEDELEG_WMASK: u64 = (1 << 0)
    | (1 << 1)
    | (1 << 2)
    | (1 << 3)
    | (1 << 4)
    | (1 << 5)
    | (1 << 6)
    | (1 << 7)
    | (1 << 8)
    | (1 << 12)
    | (1 << 13)
    | (1 << 15);

/// medeleg writable bits (bit 11, ecall-from-M, is hardwired 0).
const MEDELEG_WMASK: u64 = HEDELEG_WMASK
    | (1 << 9)
    | (1 << 10)
    | (1 << 20)
    | (1 << 21)
    | (1 << 22)
    | (1 << 23);

/// Number of guest external interrupt sources (GEILEN).
pub const GEILEN: u64 = 8;
const HGEIE_MASK: u64 = ((1 << GEILEN) - 1) << 1; // bit 0 unusable

/// The CSR backing store for one hart.
#[derive(Clone, Debug)]
pub struct CsrFile {
    /// H extension implemented (misa.H). When false the hypervisor CSRs
    /// don't exist and VS redirection never happens — this is the paper's
    /// "without VM" baseline configuration.
    pub h_enabled: bool,

    pub mstatus: u64,
    pub vsstatus: u64,
    pub medeleg: u64,
    /// Writable (S-level) part of mideleg; reads OR-in the read-only-one
    /// VS/SGEI bits when H is enabled (paper Table 1: "New read-only 1-bit
    /// fields for VS and guest external interrupts").
    pub mideleg: u64,
    pub hedeleg: u64,
    pub hideleg: u64,
    pub mie: u64,
    /// Interrupt-pending hardware register. Holds the M/S bits *and* the
    /// VS bits — hvip/hip/vsip are views into it (the aliasing the paper's
    /// check_xip_regs tests validate).
    pub mip: u64,
    pub mtvec: u64,
    pub stvec: u64,
    pub vstvec: u64,
    pub mscratch: u64,
    pub sscratch: u64,
    pub vsscratch: u64,
    pub mepc: u64,
    pub sepc: u64,
    pub vsepc: u64,
    pub mcause: u64,
    pub scause: u64,
    pub vscause: u64,
    pub mtval: u64,
    pub stval: u64,
    pub vstval: u64,
    /// Guest physical address of a faulting access, >> 2, when the trap is
    /// taken to M mode (paper Table 1).
    pub mtval2: u64,
    /// Same, when handled by HS mode (paper Table 1).
    pub htval: u64,
    pub mtinst: u64,
    pub htinst: u64,
    pub mcounteren: u64,
    pub scounteren: u64,
    pub hcounteren: u64,
    pub menvcfg: u64,
    pub senvcfg: u64,
    pub henvcfg: u64,
    pub satp: u64,
    pub vsatp: u64,
    pub hgatp: u64,
    pub hstatus: u64,
    pub hgeip: u64,
    pub hgeie: u64,
    pub htimedelta: u64,
    pub mcycle: u64,
    pub minstret: u64,
    /// Mirrored CLINT mtime (the sim loop keeps this fresh).
    pub time: u64,
    pub fcsr: u64,
    pub mhartid: u64,
}

impl CsrFile {
    pub fn new(h_enabled: bool) -> CsrFile {
        // misa: RV64 I M A F S U (+H when enabled)
        CsrFile {
            h_enabled,
            mstatus: 2 << 32 | 2 << 34, // UXL=SXL=64-bit (read-only fields)
            vsstatus: 0,
            medeleg: 0,
            mideleg: 0,
            hedeleg: 0,
            hideleg: 0,
            mie: 0,
            mip: 0,
            mtvec: 0,
            stvec: 0,
            vstvec: 0,
            mscratch: 0,
            sscratch: 0,
            vsscratch: 0,
            mepc: 0,
            sepc: 0,
            vsepc: 0,
            mcause: 0,
            scause: 0,
            vscause: 0,
            mtval: 0,
            stval: 0,
            vstval: 0,
            mtval2: 0,
            htval: 0,
            mtinst: 0,
            htinst: 0,
            mcounteren: 0,
            scounteren: 0,
            hcounteren: 0,
            menvcfg: 0,
            senvcfg: 0,
            henvcfg: 0,
            satp: 0,
            vsatp: 0,
            hgatp: 0,
            hstatus: 2 << hstatus::VSXL_SHIFT, // VSXL=64 (read-only)
            hgeip: 0,
            hgeie: 0,
            htimedelta: 0,
            mcycle: 0,
            minstret: 0,
            time: 0,
            fcsr: 0,
            mhartid: 0,
        }
    }

    pub fn misa(&self) -> u64 {
        let mut v: u64 = 2 << 62; // MXL=64
        v |= 1 << 0; // A
        v |= 1 << 5; // F
        v |= 1 << 8; // I
        v |= 1 << 12; // M
        v |= 1 << 18; // S
        v |= 1 << 20; // U
        if self.h_enabled {
            v |= 1 << 7; // H
        }
        v
    }

    /// mideleg as read by software: writable S bits, plus the
    /// read-only-one VS-level + SGEI bits when H is enabled.
    pub fn mideleg_read(&self) -> u64 {
        if self.h_enabled {
            self.mideleg | irq::VS_MASK | irq::SGEIP
        } else {
            self.mideleg
        }
    }

    /// mip as read by software: hardware bits plus the derived SGEIP bit
    /// (any enabled guest-external interrupt pending).
    pub fn mip_read(&self) -> u64 {
        let mut v = self.mip;
        if self.h_enabled && (self.hgeip & self.hgeie) != 0 {
            v |= irq::SGEIP;
        }
        v
    }

    fn sstatus_read(&self) -> u64 {
        let v = self.mstatus & SSTATUS_MASK;
        // SD summarizes FS-dirty.
        if self.mstatus & mstatus::FS_MASK == mstatus::FS_DIRTY {
            v | mstatus::SD | (2 << 32) // UXL
        } else {
            v | (2 << 32)
        }
    }

    fn vsstatus_read(&self) -> u64 {
        let v = self.vsstatus & SSTATUS_MASK;
        if self.vsstatus & mstatus::FS_MASK == mstatus::FS_DIRTY {
            v | mstatus::SD | (2 << 32)
        } else {
            v | (2 << 32)
        }
    }

    pub fn mstatus_read(&self) -> u64 {
        let v = self.mstatus;
        if v & mstatus::FS_MASK == mstatus::FS_DIRTY {
            v | mstatus::SD
        } else {
            v
        }
    }

    /// FS field helpers (paper §3.5 challenge 2: FPU access checks must
    /// consult vsstatus when V=1).
    pub fn fs_off(&self, virt: bool) -> bool {
        if self.mstatus & mstatus::FS_MASK == mstatus::FS_OFF {
            return true;
        }
        virt && (self.vsstatus & mstatus::FS_MASK == mstatus::FS_OFF)
    }

    pub fn set_fs_dirty(&mut self, virt: bool) {
        self.mstatus |= mstatus::FS_DIRTY;
        if virt {
            self.vsstatus |= mstatus::FS_DIRTY;
        }
    }

    /// Counter-enable chain for cycle/time/instret (and the paper Table 1
    /// note on hcounteren gating HPM access from the VM).
    fn counter_allowed(&self, bit: u64, prv: PrivLevel, virt: bool) -> Result<(), CsrError> {
        match prv {
            PrivLevel::Machine => Ok(()),
            PrivLevel::Supervisor | PrivLevel::User => {
                if prv == PrivLevel::User && self.scounteren & bit == 0 && !virt {
                    return Err(CsrError::Illegal);
                }
                if self.mcounteren & bit == 0 {
                    return Err(if virt { CsrError::Virtual } else { CsrError::Illegal });
                }
                if virt && self.hcounteren & bit == 0 {
                    return Err(CsrError::Virtual);
                }
                Ok(())
            }
        }
    }

    /// VS-mode redirection (§3.1: "accessing supervisor CSRs in VS mode is
    /// modified so that access is redirected to the virtual supervisor
    /// registers instead").
    fn redirect(addr: u16, virt: bool) -> u16 {
        if !virt {
            return addr;
        }
        match addr {
            CSR_SSTATUS => CSR_VSSTATUS,
            CSR_SIE => CSR_VSIE,
            CSR_STVEC => CSR_VSTVEC,
            CSR_SSCRATCH => CSR_VSSCRATCH,
            CSR_SEPC => CSR_VSEPC,
            CSR_SCAUSE => CSR_VSCAUSE,
            CSR_STVAL => CSR_VSTVAL,
            CSR_SIP => CSR_VSIP,
            CSR_SATP => CSR_VSATP,
            _ => addr,
        }
    }

    fn is_hypervisor_csr(addr: u16) -> bool {
        matches!(
            addr,
            CSR_HSTATUS
                | CSR_HEDELEG
                | CSR_HIDELEG
                | CSR_HIE
                | CSR_HTIMEDELTA
                | CSR_HCOUNTEREN
                | CSR_HGEIE
                | CSR_HENVCFG
                | CSR_HTVAL
                | CSR_HIP
                | CSR_HVIP
                | CSR_HTINST
                | CSR_HGATP
                | CSR_HGEIP
        )
    }

    fn is_vs_csr(addr: u16) -> bool {
        matches!(
            addr,
            CSR_VSSTATUS
                | CSR_VSIE
                | CSR_VSTVEC
                | CSR_VSSCRATCH
                | CSR_VSEPC
                | CSR_VSCAUSE
                | CSR_VSTVAL
                | CSR_VSIP
                | CSR_VSATP
        )
    }

    /// Privilege/permission check shared by read and write. Returns the
    /// effective (possibly redirected) address.
    fn check_access(&self, addr: u16, prv: PrivLevel, virt: bool) -> Result<u16, CsrError> {
        if (Self::is_hypervisor_csr(addr) || Self::is_vs_csr(addr)) && !self.h_enabled {
            return Err(CsrError::Illegal);
        }
        // H and VS CSRs from VS/VU raise virtual-instruction (spec / §3.1
        // "privilege protection among the registers").
        if virt && (Self::is_hypervisor_csr(addr) || Self::is_vs_csr(addr)) {
            return Err(CsrError::Virtual);
        }
        let eaddr = Self::redirect(addr, virt);
        let min = csr_min_priv_bits(addr);
        // H CSRs encode min-priv 2 but are accessible from HS (prv S, V=0).
        let effective_prv = match prv {
            PrivLevel::Machine => 3,
            PrivLevel::Supervisor => {
                if !virt && self.h_enabled {
                    2 // HS can reach min-priv-2 (hypervisor) CSRs
                } else {
                    1
                }
            }
            PrivLevel::User => 0,
        };
        if effective_prv < min {
            // U/VU or VS trying to climb: virtual-instruction if the CSR
            // *would* be accessible at the same nominal privilege without
            // V=1, otherwise plain illegal.
            if virt && min <= 2 {
                return Err(CsrError::Virtual);
            }
            return Err(CsrError::Illegal);
        }
        Ok(eaddr)
    }

    /// Read a CSR with full permission checking and VS redirection.
    pub fn read(&self, addr: u16, prv: PrivLevel, virt: bool) -> Result<u64, CsrError> {
        let eaddr = self.check_access(addr, prv, virt)?;
        match eaddr {
            CSR_CYCLE => {
                self.counter_allowed(1 << 0, prv, virt)?;
                Ok(self.mcycle)
            }
            CSR_TIME => {
                self.counter_allowed(1 << 1, prv, virt)?;
                Ok(self.time.wrapping_add(if virt { self.htimedelta } else { 0 }))
            }
            CSR_INSTRET => {
                self.counter_allowed(1 << 2, prv, virt)?;
                Ok(self.minstret)
            }
            _ => Ok(self.read_raw(eaddr)),
        }
    }

    /// Write a CSR with permission checking, redirection and write masks.
    pub fn write(&mut self, addr: u16, val: u64, prv: PrivLevel, virt: bool) -> Result<(), CsrError> {
        if csr_is_read_only(addr) {
            return Err(CsrError::Illegal);
        }
        let eaddr = self.check_access(addr, prv, virt)?;
        self.write_raw(eaddr, val);
        Ok(())
    }

    /// Unchecked read (trap unit, tests, checkpointing). Applies views and
    /// aliases but no permission checks.
    pub fn read_raw(&self, addr: u16) -> u64 {
        match addr {
            CSR_FFLAGS => self.fcsr & 0x1f,
            CSR_FRM => (self.fcsr >> 5) & 7,
            CSR_FCSR => self.fcsr & 0xff,
            CSR_SSTATUS => self.sstatus_read(),
            CSR_SIE => self.mie & irq::S_MASK,
            CSR_STVEC => self.stvec,
            CSR_SCOUNTEREN => self.scounteren,
            CSR_SENVCFG => self.senvcfg,
            CSR_SSCRATCH => self.sscratch,
            CSR_SEPC => self.sepc,
            CSR_SCAUSE => self.scause,
            CSR_STVAL => self.stval,
            CSR_SIP => self.mip_read() & irq::S_MASK,
            CSR_SATP => self.satp,
            CSR_HSTATUS => self.hstatus,
            CSR_HEDELEG => self.hedeleg,
            CSR_HIDELEG => self.hideleg,
            CSR_HIE => self.mie & irq::HS_MASK,
            CSR_HTIMEDELTA => self.htimedelta,
            CSR_HCOUNTEREN => self.hcounteren,
            CSR_HGEIE => self.hgeie,
            CSR_HENVCFG => self.henvcfg,
            CSR_HTVAL => self.htval,
            // hip: VS-level bits of mip + derived SGEIP (the paper's
            // "reading HVIP includes reading MIP" aliasing).
            CSR_HIP => self.mip_read() & irq::HS_MASK,
            CSR_HVIP => self.mip & irq::VS_MASK,
            CSR_HTINST => self.htinst,
            CSR_HGATP => self.hgatp,
            CSR_HGEIP => self.hgeip,
            CSR_VSSTATUS => self.vsstatus_read(),
            // vsip/vsie: VS bits of mip/mie, gated by hideleg, presented at
            // the S bit positions (bit 2 → bit 1, etc.).
            CSR_VSIE => (self.mie & self.hideleg & irq::VS_MASK) >> 1,
            CSR_VSTVEC => self.vstvec,
            CSR_VSSCRATCH => self.vsscratch,
            CSR_VSEPC => self.vsepc,
            CSR_VSCAUSE => self.vscause,
            CSR_VSTVAL => self.vstval,
            CSR_VSIP => (self.mip & self.hideleg & irq::VS_MASK) >> 1,
            CSR_VSATP => self.vsatp,
            CSR_MVENDORID => 0,
            CSR_MARCHID => 0x68767369, // "hvsi"
            CSR_MIMPID => 1,
            CSR_MHARTID => self.mhartid,
            CSR_MSTATUS => self.mstatus_read(),
            CSR_MISA => self.misa(),
            CSR_MEDELEG => self.medeleg,
            CSR_MIDELEG => self.mideleg_read(),
            CSR_MIE => self.mie,
            CSR_MTVEC => self.mtvec,
            CSR_MCOUNTEREN => self.mcounteren,
            CSR_MENVCFG => self.menvcfg,
            CSR_MSCRATCH => self.mscratch,
            CSR_MEPC => self.mepc,
            CSR_MCAUSE => self.mcause,
            CSR_MTVAL => self.mtval,
            CSR_MIP => self.mip_read(),
            CSR_MTINST => self.mtinst,
            CSR_MTVAL2 => self.mtval2,
            CSR_MCYCLE | CSR_CYCLE => self.mcycle,
            CSR_MINSTRET | CSR_INSTRET => self.minstret,
            CSR_TIME => self.time,
            _ => 0,
        }
    }

    /// Unchecked write. Applies the WRITE masks the paper adds (§3.1:
    /// "WRITE REGISTERS MASKS to ensure that read-only bits remain
    /// unchanged") and the alias rules.
    pub fn write_raw(&mut self, addr: u16, val: u64) {
        match addr {
            CSR_FFLAGS => self.fcsr = (self.fcsr & !0x1f) | (val & 0x1f),
            CSR_FRM => self.fcsr = (self.fcsr & !0xe0) | ((val & 7) << 5),
            CSR_FCSR => self.fcsr = val & 0xff,
            CSR_SSTATUS => {
                self.mstatus = (self.mstatus & !SSTATUS_MASK) | (val & SSTATUS_MASK);
            }
            CSR_SIE => {
                self.mie = (self.mie & !irq::S_MASK) | (val & irq::S_MASK);
            }
            CSR_STVEC => self.stvec = val & !2,
            CSR_SCOUNTEREN => self.scounteren = val & 7,
            CSR_SENVCFG => self.senvcfg = val,
            CSR_SSCRATCH => self.sscratch = val,
            CSR_SEPC => self.sepc = val & !1,
            CSR_SCAUSE => self.scause = val,
            CSR_STVAL => self.stval = val,
            CSR_SIP => {
                // Only SSIP is software-writable at S level.
                self.mip = (self.mip & !irq::SSIP) | (val & irq::SSIP);
            }
            CSR_SATP => {
                // Only Bare and Sv39 modes accepted; others leave satp
                // unchanged (WARL).
                let mode = atp::mode(val);
                if mode == atp::MODE_BARE || mode == atp::MODE_SV39 {
                    self.satp = val;
                }
            }
            CSR_HSTATUS => {
                self.hstatus = (self.hstatus & !HSTATUS_WMASK) | (val & HSTATUS_WMASK);
            }
            CSR_HEDELEG => self.hedeleg = val & HEDELEG_WMASK,
            CSR_HIDELEG => self.hideleg = val & irq::VS_MASK,
            CSR_HIE => {
                self.mie = (self.mie & !irq::HS_MASK) | (val & irq::HS_MASK);
            }
            CSR_HTIMEDELTA => self.htimedelta = val,
            CSR_HCOUNTEREN => self.hcounteren = val & 7,
            CSR_HGEIE => self.hgeie = val & HGEIE_MASK,
            CSR_HENVCFG => self.henvcfg = val,
            CSR_HTVAL => self.htval = val,
            CSR_HIP => {
                // Writable bit: VSSIP (alias of hvip.VSSIP ⇄ mip.VSSIP —
                // the aliasing chain the paper describes in §3.1).
                self.mip = (self.mip & !irq::VSSIP) | (val & irq::VSSIP);
            }
            CSR_HVIP => {
                self.mip = (self.mip & !irq::VS_MASK) | (val & irq::VS_MASK);
            }
            CSR_HTINST => self.htinst = val,
            CSR_HGATP => {
                let mode = atp::mode(val);
                if mode == atp::MODE_BARE || mode == atp::MODE_SV39X4 {
                    // Sv39x4 root is 16KiB-aligned: clear PPN[1:0] (WARL).
                    self.hgatp = val & !3;
                }
            }
            CSR_VSSTATUS => {
                self.vsstatus = (self.vsstatus & !SSTATUS_MASK) | (val & SSTATUS_MASK);
            }
            CSR_VSIE => {
                let bits = (val << 1) & self.hideleg & irq::VS_MASK;
                self.mie = (self.mie & !(self.hideleg & irq::VS_MASK)) | bits;
            }
            CSR_VSTVEC => self.vstvec = val & !2,
            CSR_VSSCRATCH => self.vsscratch = val,
            CSR_VSEPC => self.vsepc = val & !1,
            CSR_VSCAUSE => self.vscause = val,
            CSR_VSTVAL => self.vstval = val,
            CSR_VSIP => {
                // vsip.SSIP (bit 1) writes mip.VSSIP (bit 2) when delegated.
                let bit = (val << 1) & self.hideleg & irq::VSSIP;
                self.mip = (self.mip & !(self.hideleg & irq::VSSIP)) | bit;
            }
            CSR_VSATP => {
                let mode = atp::mode(val);
                if mode == atp::MODE_BARE || mode == atp::MODE_SV39 {
                    self.vsatp = val;
                }
            }
            CSR_MSTATUS => {
                let mut wmask = MSTATUS_WMASK;
                if !self.h_enabled {
                    wmask &= !(mstatus::MPV | mstatus::GVA);
                }
                let mut v = (self.mstatus & !wmask) | (val & wmask);
                // MPP is WARL: only 0/1/3.
                if (v & mstatus::MPP_MASK) >> mstatus::MPP_SHIFT == 2 {
                    v &= !mstatus::MPP_MASK;
                }
                self.mstatus = v;
            }
            CSR_MISA => {} // WARL, fixed
            CSR_MEDELEG => {
                let mask = if self.h_enabled { MEDELEG_WMASK } else { MEDELEG_WMASK & 0xffff };
                self.medeleg = val & mask;
            }
            CSR_MIDELEG => {
                // S-level bits writable; VS/SGEI bits read-only-one (H).
                self.mideleg = val & irq::S_MASK;
            }
            CSR_MIE => {
                let mask = if self.h_enabled {
                    irq::M_MASK | irq::S_MASK | irq::HS_MASK
                } else {
                    irq::M_MASK | irq::S_MASK
                };
                self.mie = val & mask;
            }
            CSR_MTVEC => self.mtvec = val & !2,
            CSR_MCOUNTEREN => self.mcounteren = val & 7,
            CSR_MENVCFG => self.menvcfg = val,
            CSR_MSCRATCH => self.mscratch = val,
            CSR_MEPC => self.mepc = val & !1,
            CSR_MCAUSE => self.mcause = val,
            CSR_MTVAL => self.mtval = val,
            CSR_MIP => {
                // Software-writable pending bits; M*IP are device-driven.
                let mask = irq::SSIP | irq::STIP | irq::SEIP | if self.h_enabled { irq::VS_MASK } else { 0 };
                self.mip = (self.mip & !mask) | (val & mask);
            }
            CSR_MTINST => self.mtinst = val,
            CSR_MTVAL2 => self.mtval2 = val,
            CSR_MCYCLE => self.mcycle = val,
            CSR_MINSTRET => self.minstret = val,
            _ => {}
        }
    }

    /// Device-driven interrupt lines (CLINT/PLIC): set/clear the read-only
    /// M-level pending bits.
    pub fn set_mip_bits(&mut self, bits: u64) {
        self.mip |= bits;
    }
    pub fn clear_mip_bits(&mut self, bits: u64) {
        self.mip &= !bits;
    }

    /// Snapshot the per-guest VS/H CSR world (used by the vmm world-switch
    /// engine): the whole vs* bank, the hypervisor-configuration CSRs
    /// (including `hgatp` with its VMID) and the VS-level pending/enable
    /// interrupt bits of mip/mie.
    pub fn vs_save(&self) -> VsCsrFile {
        VsCsrFile {
            vsstatus: self.vsstatus,
            vstvec: self.vstvec,
            vsscratch: self.vsscratch,
            vsepc: self.vsepc,
            vscause: self.vscause,
            vstval: self.vstval,
            vsatp: self.vsatp,
            hstatus: self.hstatus,
            hedeleg: self.hedeleg,
            hideleg: self.hideleg,
            hgatp: self.hgatp,
            htval: self.htval,
            htinst: self.htinst,
            htimedelta: self.htimedelta,
            hcounteren: self.hcounteren,
            henvcfg: self.henvcfg,
            hgeie: self.hgeie,
            hgeip: self.hgeip,
            vs_mip: self.mip & irq::VS_MASK,
            vs_mie: self.mie & irq::VS_MASK,
        }
    }

    /// Restore a snapshot taken with [`CsrFile::vs_save`].
    pub fn vs_restore(&mut self, s: &VsCsrFile) {
        self.vsstatus = s.vsstatus;
        self.vstvec = s.vstvec;
        self.vsscratch = s.vsscratch;
        self.vsepc = s.vsepc;
        self.vscause = s.vscause;
        self.vstval = s.vstval;
        self.vsatp = s.vsatp;
        self.hstatus = s.hstatus;
        self.hedeleg = s.hedeleg;
        self.hideleg = s.hideleg;
        self.hgatp = s.hgatp;
        self.htval = s.htval;
        self.htinst = s.htinst;
        self.htimedelta = s.htimedelta;
        self.hcounteren = s.hcounteren;
        self.henvcfg = s.henvcfg;
        self.hgeie = s.hgeie;
        self.hgeip = s.hgeip;
        self.mip = (self.mip & !irq::VS_MASK) | (s.vs_mip & irq::VS_MASK);
        self.mie = (self.mie & !irq::VS_MASK) | (s.vs_mie & irq::VS_MASK);
    }

    /// Bulk world-switch primitive: exchange the live VS/H CSR file with a
    /// parked vCPU's in one call (the paper-adjacent "world switch" cost
    /// the vmm benchmarks measure).
    pub fn vs_swap(&mut self, s: &mut VsCsrFile) {
        let current = self.vs_save();
        self.vs_restore(s);
        *s = current;
    }
}

/// The bulk-swappable per-guest VS/H CSR state — everything `hgatp`-tagged
/// world switching must replace (GPRs/pc/mode live in [`crate::cpu::Hart`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VsCsrFile {
    pub vsstatus: u64,
    pub vstvec: u64,
    pub vsscratch: u64,
    pub vsepc: u64,
    pub vscause: u64,
    pub vstval: u64,
    pub vsatp: u64,
    pub hstatus: u64,
    pub hedeleg: u64,
    pub hideleg: u64,
    pub hgatp: u64,
    pub htval: u64,
    pub htinst: u64,
    pub htimedelta: u64,
    pub hcounteren: u64,
    pub henvcfg: u64,
    pub hgeie: u64,
    pub hgeip: u64,
    /// VS-level bits of mip (hvip view), at their native bit positions.
    pub vs_mip: u64,
    /// VS-level bits of mie, at their native bit positions.
    pub vs_mie: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::PrivLevel as P;

    fn csr() -> CsrFile {
        CsrFile::new(true)
    }

    #[test]
    fn mideleg_vs_bits_read_only_one() {
        let mut c = csr();
        c.write(CSR_MIDELEG, 0, P::Machine, false).unwrap();
        // Paper Table 1: VS + guest-external bits are read-only 1.
        assert_eq!(c.mideleg_read() & irq::VS_MASK, irq::VS_MASK);
        assert_eq!(c.mideleg_read() & irq::SGEIP, irq::SGEIP);
        c.write(CSR_MIDELEG, irq::S_MASK | irq::M_MASK, P::Machine, false).unwrap();
        assert_eq!(c.mideleg_read() & irq::M_MASK, 0, "M bits never delegable");
        assert_eq!(c.mideleg_read() & irq::S_MASK, irq::S_MASK);
    }

    #[test]
    fn mideleg_without_h_has_no_forced_bits() {
        let mut c = CsrFile::new(false);
        c.write(CSR_MIDELEG, 0, P::Machine, false).unwrap();
        assert_eq!(c.mideleg_read(), 0);
    }

    #[test]
    fn hvip_aliases_mip() {
        // Paper §3.1: "reading the HVIP CSR includes reading the MIP CSR
        // because the VSSIP bit of HVIP is an alias of the VSSIP bit in MIP".
        let mut c = csr();
        c.write(CSR_HVIP, irq::VSSIP | irq::VSTIP, P::Supervisor, false).unwrap();
        assert_eq!(c.read(CSR_MIP, P::Machine, false).unwrap() & irq::VS_MASK, irq::VSSIP | irq::VSTIP);
        assert_eq!(c.read(CSR_HIP, P::Supervisor, false).unwrap() & irq::VS_MASK, irq::VSSIP | irq::VSTIP);
        // And writing mip's VS bits shows up in hvip.
        c.write(CSR_MIP, irq::VSEIP, P::Machine, false).unwrap();
        assert_eq!(c.read(CSR_HVIP, P::Supervisor, false).unwrap(), irq::VSEIP);
    }

    #[test]
    fn vsip_is_shifted_view_gated_by_hideleg() {
        let mut c = csr();
        c.write_raw(CSR_HVIP, irq::VSSIP);
        // Not delegated: vsip reads 0.
        assert_eq!(c.read_raw(CSR_VSIP), 0);
        c.write_raw(CSR_HIDELEG, irq::VS_MASK);
        // Delegated: appears at the S position (bit 1).
        assert_eq!(c.read_raw(CSR_VSIP), irq::SSIP);
        // Write through: vsip.SSIP sets mip.VSSIP.
        c.write_raw(CSR_VSIP, 0);
        assert_eq!(c.mip & irq::VSSIP, 0);
    }

    #[test]
    fn vs_mode_supervisor_access_redirects() {
        // §3.1: VS-mode access to sstatus/etc. goes to the vs* bank.
        let mut c = csr();
        c.write(CSR_SSCRATCH, 0xabcd, P::Supervisor, true).unwrap();
        assert_eq!(c.vsscratch, 0xabcd);
        assert_eq!(c.sscratch, 0);
        assert_eq!(c.read(CSR_SSCRATCH, P::Supervisor, true).unwrap(), 0xabcd);
        // satp from VS touches vsatp.
        let v = (atp::MODE_SV39 << atp::MODE_SHIFT) | 0x80000;
        c.write(CSR_SATP, v, P::Supervisor, true).unwrap();
        assert_eq!(c.vsatp, v);
        assert_eq!(c.satp, 0);
    }

    #[test]
    fn vs_access_to_h_csrs_is_virtual_exception() {
        let c = csr();
        assert_eq!(c.read(CSR_HSTATUS, P::Supervisor, true), Err(CsrError::Virtual));
        assert_eq!(c.read(CSR_VSSTATUS, P::Supervisor, true), Err(CsrError::Virtual));
        assert_eq!(c.read(CSR_HGATP, P::Supervisor, true), Err(CsrError::Virtual));
        // From HS (V=0) they're fine.
        assert!(c.read(CSR_HSTATUS, P::Supervisor, false).is_ok());
        // M CSRs from VS: virtual? No — M CSRs are min-priv 3; from VS the
        // access could never succeed at S level either → illegal per spec.
        // (min > 2 ⇒ Illegal)
        assert_eq!(c.read(CSR_MSTATUS, P::Supervisor, true), Err(CsrError::Illegal));
    }

    #[test]
    fn h_csrs_illegal_without_h() {
        let c = CsrFile::new(false);
        assert_eq!(c.read(CSR_HSTATUS, P::Machine, false), Err(CsrError::Illegal));
        assert_eq!(c.read(CSR_VSATP, P::Machine, false), Err(CsrError::Illegal));
    }

    #[test]
    fn user_cannot_touch_supervisor() {
        let mut c = csr();
        assert_eq!(c.read(CSR_SSTATUS, P::User, false), Err(CsrError::Illegal));
        assert_eq!(c.write(CSR_SATP, 0, P::User, false), Err(CsrError::Illegal));
        // VU → virtual-instruction for S CSRs (min ≤ 2 and V=1).
        assert_eq!(c.read(CSR_SSTATUS, P::User, true), Err(CsrError::Virtual));
    }

    #[test]
    fn read_only_csrs_reject_writes() {
        let mut c = csr();
        assert_eq!(c.write(CSR_MHARTID, 5, P::Machine, false), Err(CsrError::Illegal));
        assert_eq!(c.write(CSR_HGEIP, 5, P::Machine, false), Err(CsrError::Illegal));
    }

    #[test]
    fn write_masks_protect_read_only_fields() {
        let mut c = csr();
        // hedeleg: ecall-from-HS/VS/M and guest-page-fault bits hardwired 0.
        c.write(CSR_HEDELEG, u64::MAX, P::Supervisor, false).unwrap();
        assert_eq!(c.hedeleg & (1 << 9 | 1 << 10 | 1 << 11), 0);
        assert_eq!(c.hedeleg & (0xf << 20), 0);
        assert_ne!(c.hedeleg & (1 << 12), 0, "inst page fault delegable");
        // medeleg bit 11 hardwired 0, guest-page-faults delegable.
        c.write(CSR_MEDELEG, u64::MAX, P::Machine, false).unwrap();
        assert_eq!(c.medeleg & (1 << 11), 0);
        assert_ne!(c.medeleg & (1 << 21), 0);
        // mstatus.MPP = 2 is invalid (WARL → 0).
        c.write(CSR_MSTATUS, 2 << mstatus::MPP_SHIFT, P::Machine, false).unwrap();
        assert_eq!(c.mstatus & mstatus::MPP_MASK, 0);
    }

    #[test]
    fn sgeip_is_derived_from_hgeip_and_hgeie() {
        let mut c = csr();
        c.hgeip = 1 << 2;
        assert_eq!(c.mip_read() & irq::SGEIP, 0);
        c.write_raw(CSR_HGEIE, 1 << 2);
        assert_ne!(c.mip_read() & irq::SGEIP, 0);
        assert_ne!(c.read_raw(CSR_HIP) & irq::SGEIP, 0);
    }

    #[test]
    fn hgatp_warl_alignment() {
        let mut c = csr();
        c.write_raw(CSR_HGATP, (atp::MODE_SV39X4 << atp::MODE_SHIFT) | 0x80003);
        assert_eq!(atp::ppn(c.hgatp) & 3, 0, "Sv39x4 root must be 16KiB aligned");
        // Unsupported mode leaves it unchanged.
        let before = c.hgatp;
        c.write_raw(CSR_HGATP, 5 << atp::MODE_SHIFT);
        assert_eq!(c.hgatp, before);
    }

    #[test]
    fn fs_checks_consult_vsstatus_when_virt() {
        let mut c = csr();
        c.mstatus |= mstatus::FS_INITIAL;
        c.vsstatus &= !mstatus::FS_MASK; // vs FS = Off
        assert!(!c.fs_off(false), "native: mstatus.FS on");
        assert!(c.fs_off(true), "guest: vsstatus.FS off must gate FPU (§3.5)");
        c.vsstatus |= mstatus::FS_INITIAL;
        assert!(!c.fs_off(true));
    }

    #[test]
    fn counter_gating_chain() {
        let mut c = csr();
        // No enables: S read of time is illegal; VS read is virtual once
        // mcounteren allows but hcounteren doesn't.
        assert_eq!(c.read(CSR_TIME, P::Supervisor, false), Err(CsrError::Illegal));
        c.mcounteren = 7;
        assert!(c.read(CSR_TIME, P::Supervisor, false).is_ok());
        assert_eq!(c.read(CSR_TIME, P::Supervisor, true), Err(CsrError::Virtual));
        c.hcounteren = 7;
        assert!(c.read(CSR_TIME, P::Supervisor, true).is_ok());
    }

    #[test]
    fn time_applies_htimedelta_for_guest() {
        let mut c = csr();
        c.time = 1000;
        c.htimedelta = 234;
        c.mcounteren = 7;
        c.hcounteren = 7;
        assert_eq!(c.read(CSR_TIME, P::Supervisor, false).unwrap(), 1000);
        assert_eq!(c.read(CSR_TIME, P::Supervisor, true).unwrap(), 1234);
    }

    #[test]
    fn vs_swap_exchanges_guest_worlds() {
        let mut c = csr();
        c.write_raw(CSR_VSSCRATCH, 0x1111);
        c.write_raw(CSR_VSATP, (atp::MODE_SV39 << atp::MODE_SHIFT) | 0x100);
        c.write_raw(CSR_HGATP, (atp::MODE_SV39X4 << atp::MODE_SHIFT) | (1 << atp::VMID_SHIFT) | 0x200);
        c.write_raw(CSR_HVIP, irq::VSSIP);
        let mut parked = crate::cpu::csr::VsCsrFile {
            vsscratch: 0x2222,
            hgatp: (atp::MODE_SV39X4 << atp::MODE_SHIFT) | (2 << atp::VMID_SHIFT) | 0x300,
            vs_mip: irq::VSTIP,
            ..Default::default()
        };
        c.vs_swap(&mut parked);
        // Live CSR file now holds the parked guest.
        assert_eq!(c.vsscratch, 0x2222);
        assert_eq!(atp::vmid(c.hgatp), 2);
        assert_eq!(c.mip & irq::VS_MASK, irq::VSTIP);
        // The snapshot captured the previous guest, VMID and pending bits
        // included.
        assert_eq!(parked.vsscratch, 0x1111);
        assert_eq!(atp::vmid(parked.hgatp), 1);
        assert_eq!(parked.vs_mip, irq::VSSIP);
        // Round trip restores the original world exactly.
        c.vs_swap(&mut parked);
        assert_eq!(c.vsscratch, 0x1111);
        assert_eq!(atp::vmid(c.hgatp), 1);
        assert_eq!(c.mip & irq::VS_MASK, irq::VSSIP);
    }

    #[test]
    fn vs_save_does_not_leak_non_vs_irq_bits() {
        let mut c = csr();
        c.mip = irq::MTIP | irq::SSIP | irq::VSTIP;
        c.mie = irq::MTIP | irq::VSSIP;
        let s = c.vs_save();
        assert_eq!(s.vs_mip, irq::VSTIP);
        assert_eq!(s.vs_mie, irq::VSSIP);
        // Restoring another guest's VS bits must keep M/S bits intact.
        let other = crate::cpu::csr::VsCsrFile::default();
        c.vs_restore(&other);
        assert_eq!(c.mip & (irq::MTIP | irq::SSIP), irq::MTIP | irq::SSIP);
        assert_eq!(c.mip & irq::VS_MASK, 0);
        assert_eq!(c.mie & irq::MTIP, irq::MTIP);
    }

    #[test]
    fn sd_bit_reflects_fs_dirty() {
        let mut c = csr();
        c.set_fs_dirty(false);
        assert_ne!(c.read_raw(CSR_MSTATUS) & mstatus::SD, 0);
        assert_ne!(c.read_raw(CSR_SSTATUS) & mstatus::SD, 0);
    }
}
