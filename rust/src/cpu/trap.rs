//! Trap entry and return — the analog of gem5's `RiscvFault::invoke()`
//! extended for the H extension (paper §3.2): delegation through
//! medeleg/mideleg, then hedeleg/hideleg when V=1; new status/cause/tval
//! writes including htval/mtval2 (guest physical address >> 2), GVA and MPV
//! in mstatus, SPV/SPVP/GVA in hstatus, and tinst values.

use crate::isa::csr::{hstatus, mstatus};
use crate::isa::{Exception, InterruptCause, PrivLevel};

use super::Hart;

/// Where a trap lands (paper Fig. 2's three handler levels).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrapTarget {
    M,
    HS,
    VS,
}

impl TrapTarget {
    pub fn name(self) -> &'static str {
        match self {
            TrapTarget::M => "M",
            TrapTarget::HS => "HS",
            TrapTarget::VS => "VS",
        }
    }
}

/// Select the privilege level that handles a synchronous exception, by
/// walking the delegation chain: M unless medeleg[code]; then HS unless
/// (V=1 and hedeleg[code]); then VS.
pub fn exception_target(hart: &Hart, code: u64) -> TrapTarget {
    if hart.prv == PrivLevel::Machine {
        return TrapTarget::M;
    }
    let bit = 1u64 << code;
    if hart.csr.medeleg & bit == 0 {
        return TrapTarget::M;
    }
    if hart.virt && hart.csr.h_enabled && hart.csr.hedeleg & bit != 0 {
        return TrapTarget::VS;
    }
    TrapTarget::HS
}

/// Take a synchronous exception: write the status/cause/tval registers of
/// the destination level, switch (prv, V) and jump to the trap vector.
pub fn take_exception(hart: &mut Hart, exc: &Exception) -> TrapTarget {
    let target = exception_target(hart, exc.cause.code());
    enter_trap(hart, target, exc.cause.code(), false, exc.tval, exc.gpa, exc.gva, exc.tinst);
    target
}

/// Take an interrupt whose destination was already computed by
/// `check_interrupts` (paper Fig. 2).
pub fn take_interrupt(hart: &mut Hart, cause: InterruptCause, target: TrapTarget) {
    // When a VS-level interrupt is taken into VS mode, the cause is
    // presented using the *supervisor* encoding (VSSI→SSI etc.).
    let code = match (target, cause) {
        (TrapTarget::VS, InterruptCause::VirtualSupervisorSoft) => 1,
        (TrapTarget::VS, InterruptCause::VirtualSupervisorTimer) => 5,
        (TrapTarget::VS, InterruptCause::VirtualSupervisorExternal) => 9,
        _ => cause.code(),
    };
    enter_trap(hart, target, code, true, 0, 0, false, 0);
}

const CAUSE_INTERRUPT_BIT: u64 = 1 << 63;

#[allow(clippy::too_many_arguments)]
fn enter_trap(
    hart: &mut Hart,
    target: TrapTarget,
    code: u64,
    is_interrupt: bool,
    tval: u64,
    gpa: u64,
    gva: bool,
    tinst: u64,
) {
    let cause = if is_interrupt { code | CAUSE_INTERRUPT_BIT } else { code };
    let from_prv = hart.prv;
    let from_virt = hart.virt;
    match target {
        TrapTarget::M => {
            let c = &mut hart.csr;
            // mstatus: MPV ← V, GVA ← gva (paper Table 1), MPP ← prv,
            // MPIE ← MIE, MIE ← 0.
            let mut st = c.mstatus;
            st &= !(mstatus::MPV | mstatus::GVA | mstatus::MPP_MASK | mstatus::MPIE);
            if from_virt {
                st |= mstatus::MPV;
            }
            if gva {
                st |= mstatus::GVA;
            }
            st |= from_prv.bits() << mstatus::MPP_SHIFT;
            if st & mstatus::MIE != 0 {
                st |= mstatus::MPIE;
            }
            st &= !mstatus::MIE;
            c.mstatus = st;
            c.mepc = hart.pc;
            c.mcause = cause;
            c.mtval = tval;
            // Guest physical address >> 2 "when the fault is handled by
            // M mode" (paper Table 1: mtval2).
            c.mtval2 = gpa >> 2;
            c.mtinst = tinst;
            hart.virt = false;
            hart.prv = PrivLevel::Machine;
            hart.pc = vector(c.mtvec, is_interrupt, code);
        }
        TrapTarget::HS => {
            let c = &mut hart.csr;
            // hstatus: SPV ← V, SPVP ← prv (only updated when V=1),
            // GVA ← gva (paper Table 1: hstatus "manages the exception
            // handling behavior of a VS mode guest").
            let mut hs = c.hstatus;
            hs &= !(hstatus::SPV | hstatus::GVA);
            if from_virt {
                hs |= hstatus::SPV;
                hs &= !hstatus::SPVP;
                if from_prv == PrivLevel::Supervisor {
                    hs |= hstatus::SPVP;
                }
            }
            if gva {
                hs |= hstatus::GVA;
            }
            c.hstatus = hs;
            // sstatus side (stored in mstatus): SPP ← prv, SPIE ← SIE,
            // SIE ← 0.
            let mut st = c.mstatus;
            st &= !(mstatus::SPP | mstatus::SPIE);
            if from_prv == PrivLevel::Supervisor {
                st |= mstatus::SPP;
            }
            if st & mstatus::SIE != 0 {
                st |= mstatus::SPIE;
            }
            st &= !mstatus::SIE;
            c.mstatus = st;
            c.sepc = hart.pc;
            c.scause = cause;
            c.stval = tval;
            // Guest physical address >> 2 "when the fault is handled by
            // HS mode" (paper Table 1: htval).
            c.htval = gpa >> 2;
            c.htinst = tinst;
            hart.virt = false;
            hart.prv = PrivLevel::Supervisor;
            hart.pc = vector(c.stvec, is_interrupt, code);
        }
        TrapTarget::VS => {
            debug_assert!(from_virt, "VS trap target only reachable from VS/VU");
            let c = &mut hart.csr;
            let mut st = c.vsstatus;
            st &= !(mstatus::SPP | mstatus::SPIE);
            if from_prv == PrivLevel::Supervisor {
                st |= mstatus::SPP;
            }
            if st & mstatus::SIE != 0 {
                st |= mstatus::SPIE;
            }
            st &= !mstatus::SIE;
            c.vsstatus = st;
            c.vsepc = hart.pc;
            c.vscause = cause;
            c.vstval = tval;
            hart.virt = true;
            hart.prv = PrivLevel::Supervisor;
            hart.pc = vector(c.vstvec, is_interrupt, code);
        }
    }
}

fn vector(tvec: u64, is_interrupt: bool, code: u64) -> u64 {
    let base = tvec & !3;
    if is_interrupt && tvec & 1 == 1 {
        base + 4 * code
    } else {
        base
    }
}

/// MRET: return from an M-mode trap handler. Restores (prv, V) from
/// (MPP, MPV) per the H-extension rules.
pub fn mret(hart: &mut Hart) {
    let c = &mut hart.csr;
    let st = c.mstatus;
    let mpp = PrivLevel::from_bits((st & mstatus::MPP_MASK) >> mstatus::MPP_SHIFT);
    let mpv = st & mstatus::MPV != 0;
    let mut new = st;
    // MIE ← MPIE, MPIE ← 1, MPP ← U, MPV ← 0; MPRV cleared when leaving M.
    new &= !mstatus::MIE;
    if st & mstatus::MPIE != 0 {
        new |= mstatus::MIE;
    }
    new |= mstatus::MPIE;
    new &= !(mstatus::MPP_MASK | mstatus::MPV);
    if mpp != PrivLevel::Machine {
        new &= !mstatus::MPRV;
    }
    c.mstatus = new;
    hart.prv = mpp;
    hart.virt = c.h_enabled && mpv && mpp != PrivLevel::Machine;
    hart.pc = c.mepc;
}

/// SRET executed with V=0 (HS mode): restores V from hstatus.SPV.
pub fn sret_hs(hart: &mut Hart) {
    let c = &mut hart.csr;
    let st = c.mstatus;
    let spp = if st & mstatus::SPP != 0 { PrivLevel::Supervisor } else { PrivLevel::User };
    let spv = c.hstatus & hstatus::SPV != 0;
    let mut new = st;
    new &= !mstatus::SIE;
    if st & mstatus::SPIE != 0 {
        new |= mstatus::SIE;
    }
    new |= mstatus::SPIE;
    new &= !mstatus::SPP;
    if spp != PrivLevel::Machine {
        new &= !mstatus::MPRV;
    }
    c.mstatus = new;
    c.hstatus &= !hstatus::SPV;
    hart.prv = if c.h_enabled && spv {
        // Returning into the guest: privilege comes from hstatus.SPVP.
        if c.hstatus & hstatus::SPVP != 0 {
            PrivLevel::Supervisor
        } else {
            PrivLevel::User
        }
    } else {
        spp
    };
    hart.virt = c.h_enabled && spv;
    hart.pc = c.sepc;
}

/// SRET executed with V=1 (VS mode): uses the vsstatus bank, stays V=1.
pub fn sret_vs(hart: &mut Hart) {
    let c = &mut hart.csr;
    let st = c.vsstatus;
    let spp = if st & mstatus::SPP != 0 { PrivLevel::Supervisor } else { PrivLevel::User };
    let mut new = st;
    new &= !mstatus::SIE;
    if st & mstatus::SPIE != 0 {
        new |= mstatus::SIE;
    }
    new |= mstatus::SPIE;
    new &= !mstatus::SPP;
    c.vsstatus = new;
    hart.prv = spp;
    hart.virt = true;
    hart.pc = c.vsepc;
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::isa::ExceptionCause;

    fn hart_at(prv: PrivLevel, virt: bool) -> Hart {
        let mut h = Hart::new(true);
        h.prv = prv;
        h.virt = virt;
        h.pc = 0x8000_1000;
        h.csr.mtvec = 0x8000_0100;
        h.csr.stvec = 0x8000_0200;
        h.csr.vstvec = 0x8000_0300;
        h
    }

    #[test]
    fn undelegated_exception_goes_to_m() {
        let mut h = hart_at(PrivLevel::Supervisor, false);
        let t = take_exception(&mut h, &Exception::new(ExceptionCause::IllegalInst, 0xbad));
        assert_eq!(t, TrapTarget::M);
        assert_eq!(h.prv, PrivLevel::Machine);
        assert_eq!(h.pc, 0x8000_0100);
        assert_eq!(h.csr.mcause, 2);
        assert_eq!(h.csr.mtval, 0xbad);
        assert_eq!(h.csr.mepc, 0x8000_1000);
        // MPP records S.
        assert_eq!((h.csr.mstatus & mstatus::MPP_MASK) >> mstatus::MPP_SHIFT, 1);
        assert_eq!(h.csr.mstatus & mstatus::MPV, 0);
    }

    #[test]
    fn medeleg_sends_to_hs_and_hedeleg_to_vs() {
        // Page fault from VS with medeleg set but hedeleg clear → HS.
        let mut h = hart_at(PrivLevel::Supervisor, true);
        h.csr.medeleg = 1 << 13;
        let t = take_exception(&mut h, &Exception::new(ExceptionCause::LoadPageFault, 0x42));
        assert_eq!(t, TrapTarget::HS);
        assert!(!h.virt, "trap to HS clears V");
        assert_eq!(h.csr.scause, 13);
        assert_ne!(h.csr.hstatus & hstatus::SPV, 0, "SPV records V=1");
        assert_ne!(h.csr.hstatus & hstatus::SPVP, 0, "SPVP records VS");

        // Same but hedeleg set → VS, V stays 1.
        let mut h = hart_at(PrivLevel::Supervisor, true);
        h.csr.medeleg = 1 << 13;
        h.csr.hedeleg = 1 << 13;
        let t = take_exception(&mut h, &Exception::new(ExceptionCause::LoadPageFault, 0x42));
        assert_eq!(t, TrapTarget::VS);
        assert!(h.virt);
        assert_eq!(h.csr.vscause, 13);
        assert_eq!(h.csr.vstval, 0x42);
        assert_eq!(h.pc, 0x8000_0300);
    }

    #[test]
    fn hedeleg_ignored_when_not_virtualized() {
        let mut h = hart_at(PrivLevel::Supervisor, false);
        h.csr.medeleg = 1 << 13;
        h.csr.hedeleg = 1 << 13;
        let t = take_exception(&mut h, &Exception::new(ExceptionCause::LoadPageFault, 0x42));
        assert_eq!(t, TrapTarget::HS, "hedeleg only applies when V=1");
    }

    #[test]
    fn guest_page_fault_writes_htval_or_mtval2_shifted() {
        // Handled at HS: htval = gpa >> 2 (paper Table 1).
        let mut h = hart_at(PrivLevel::Supervisor, true);
        h.csr.medeleg = 1 << ExceptionCause::LoadGuestPageFault.code();
        let exc = Exception::new(ExceptionCause::LoadGuestPageFault, 0x5000)
            .with_gpa(0x9_2000)
            .with_gva(true)
            .with_tinst(0x3020_3083);
        let t = take_exception(&mut h, &exc);
        assert_eq!(t, TrapTarget::HS);
        assert_eq!(h.csr.htval, 0x9_2000 >> 2);
        assert_eq!(h.csr.htinst, 0x3020_3083);
        assert_ne!(h.csr.hstatus & hstatus::GVA, 0);

        // Handled at M: mtval2 (paper Table 1).
        let mut h = hart_at(PrivLevel::Supervisor, true);
        let t = take_exception(&mut h, &exc);
        assert_eq!(t, TrapTarget::M);
        assert_eq!(h.csr.mtval2, 0x9_2000 >> 2);
        assert_eq!(h.csr.mtinst, 0x3020_3083);
        assert_ne!(h.csr.mstatus & mstatus::GVA, 0);
        assert_ne!(h.csr.mstatus & mstatus::MPV, 0);
    }

    #[test]
    fn interrupt_cause_translated_for_vs() {
        let mut h = hart_at(PrivLevel::Supervisor, true);
        take_interrupt(&mut h, InterruptCause::VirtualSupervisorTimer, TrapTarget::VS);
        assert_eq!(h.csr.vscause, 5 | CAUSE_INTERRUPT_BIT, "VSTI presented as STI in VS");
        assert!(h.virt);
        let mut h = hart_at(PrivLevel::Supervisor, true);
        take_interrupt(&mut h, InterruptCause::VirtualSupervisorTimer, TrapTarget::HS);
        assert_eq!(h.csr.scause, 6 | CAUSE_INTERRUPT_BIT, "VSTI keeps code 6 at HS");
    }

    #[test]
    fn vectored_interrupt_dispatch() {
        let mut h = hart_at(PrivLevel::Supervisor, false);
        h.csr.mtvec = 0x8000_0100 | 1; // vectored
        take_interrupt(&mut h, InterruptCause::MachineTimer, TrapTarget::M);
        assert_eq!(h.pc, 0x8000_0100 + 4 * 7);
    }

    #[test]
    fn mret_restores_virtualization() {
        let mut h = hart_at(PrivLevel::Machine, false);
        h.csr.mepc = 0x9000_0000;
        h.csr.mstatus |= (1 << mstatus::MPP_SHIFT) | mstatus::MPV | mstatus::MPIE;
        mret(&mut h);
        assert_eq!(h.prv, PrivLevel::Supervisor);
        assert!(h.virt, "MPV=1, MPP=S → VS mode");
        assert_eq!(h.pc, 0x9000_0000);
        assert_ne!(h.csr.mstatus & mstatus::MIE, 0, "MIE ← MPIE");
        assert_eq!(h.csr.mstatus & mstatus::MPV, 0, "MPV cleared");
    }

    #[test]
    fn mret_to_machine_ignores_mpv() {
        let mut h = hart_at(PrivLevel::Machine, false);
        h.csr.mstatus |= (3 << mstatus::MPP_SHIFT) | mstatus::MPV;
        mret(&mut h);
        assert_eq!(h.prv, PrivLevel::Machine);
        assert!(!h.virt);
    }

    #[test]
    fn sret_hs_enters_guest() {
        let mut h = hart_at(PrivLevel::Supervisor, false);
        h.csr.sepc = 0x1000;
        h.csr.hstatus |= hstatus::SPV | hstatus::SPVP;
        h.csr.mstatus |= mstatus::SPP | mstatus::SPIE;
        sret_hs(&mut h);
        assert!(h.virt, "SPV=1 → enter guest");
        assert_eq!(h.prv, PrivLevel::Supervisor, "SPVP=1 → VS");
        assert_eq!(h.pc, 0x1000);
        assert_eq!(h.csr.hstatus & hstatus::SPV, 0);
    }

    #[test]
    fn sret_vs_stays_virtualized() {
        let mut h = hart_at(PrivLevel::Supervisor, true);
        h.csr.vsepc = 0x2000;
        h.csr.vsstatus |= mstatus::SPP | mstatus::SPIE;
        sret_vs(&mut h);
        assert!(h.virt);
        assert_eq!(h.prv, PrivLevel::Supervisor);
        assert_eq!(h.pc, 0x2000);
        assert_ne!(h.csr.vsstatus & mstatus::SIE, 0, "SIE ← SPIE in vsstatus bank");
    }

    #[test]
    fn trap_to_hs_from_u_clears_spvp_path() {
        // From VU: SPVP must record U.
        let mut h = hart_at(PrivLevel::User, true);
        h.csr.medeleg = 1 << 8;
        let t = take_exception(&mut h, &Exception::new(ExceptionCause::EcallFromU, 0));
        assert_eq!(t, TrapTarget::HS);
        assert_ne!(h.csr.hstatus & hstatus::SPV, 0);
        assert_eq!(h.csr.hstatus & hstatus::SPVP, 0, "SPVP=U");
        assert_eq!(h.csr.mstatus & mstatus::SPP, 0);
    }
}
