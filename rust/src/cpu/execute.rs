//! Instruction semantics for the functional (atomic) CPU — the analog of
//! gem5's atomic CPU tick plus the H-extension behaviors the paper adds:
//! trapping rules for wfi/sret/sfence under virtualization, hypervisor
//! load/store (HLV/HSV/HLVX) with forced-virtualization translation, hfence
//! TLB maintenance, and FS-field FPU gating that consults vsstatus when
//! V=1 (§3.5 challenge 2).

use crate::isa::csr::{self as csrdef, atp, hstatus, mstatus};
use crate::isa::{decode, Exception, ExceptionCause, Inst, InterruptCause, Op, PrivLevel};
use crate::mem::Bus;
use crate::mmu::{self, Access, MmuStats, Tlb, TranslateCtx, XlateFlags};

use super::interrupts::{check_interrupts, wfi_wakeup};
use super::trap::{self, TrapTarget};
use super::{CsrError, Hart};

/// A one-entry page-translation cache in front of the TLB (§Perf): valid
/// for one (vpn, privilege, V, SUM/MXR, TLB-generation) tuple. The TLB
/// generation changes on every flush, so stale translations can never be
/// served (RISC-V permits serving pre-sfence translations otherwise).
#[derive(Clone, Copy, Default)]
struct PageCache {
    valid: bool,
    vpn: u64,
    pa_page: u64,
    prv: u8,
    virt: bool,
    sum_mxr: u8,
    gen: u64,
}

impl PageCache {
    #[inline]
    fn hit(&self, vpn: u64, prv: u8, virt: bool, sum_mxr: u8, gen: u64) -> bool {
        self.valid
            && self.vpn == vpn
            && self.prv == prv
            && self.virt == virt
            && self.sum_mxr == sum_mxr
            && self.gen == gen
    }
}

/// One hart plus its private MMU state (TLB + walker counters).
pub struct Core {
    pub hart: Hart,
    pub tlb: Tlb,
    pub mmu_stats: MmuStats,
    /// Optional virtual-reference trace (fetch/load/store) feeding the XLA
    /// analytics model — see [`crate::trace`].
    pub trace: Option<crate::trace::TraceBuf>,
    /// Predecoded basic blocks (the block engine; see [`super::block`]).
    /// Like every cache below, derived state: reachable entries are keyed
    /// by the TLB generation, so flushes and world switches orphan them.
    pub block_cache: super::block::BlockCache,
    /// Decoded-instruction cache keyed by raw bits (hot-path optimization;
    /// see DESIGN.md §Perf).
    decode_cache: Vec<(u32, Inst)>,
    fetch_cache: PageCache,
    load_cache: PageCache,
    store_cache: PageCache,
}

const DECODE_CACHE_SIZE: usize = 8192;

impl Core {
    pub fn new(h_enabled: bool) -> Core {
        // The sentinel tag must be self-consistent: any 32-bit value can be
        // fetched, so seed every slot with a real (tag, decode(tag)) pair.
        Core {
            hart: Hart::new(h_enabled),
            tlb: Tlb::default(),
            mmu_stats: MmuStats::default(),
            trace: None,
            block_cache: super::block::BlockCache::new(),
            decode_cache: vec![(0xffff_ffff, decode(0xffff_ffff)); DECODE_CACHE_SIZE],
            fetch_cache: PageCache::default(),
            load_cache: PageCache::default(),
            store_cache: PageCache::default(),
        }
    }

    #[inline]
    fn decode_cached(&mut self, raw: u32) -> Inst {
        let idx = (raw as usize ^ (raw as usize >> 13)) & (DECODE_CACHE_SIZE - 1);
        let (tag, inst) = self.decode_cache[idx];
        if tag == raw {
            return inst;
        }
        let inst = decode(raw);
        self.decode_cache[idx] = (raw, inst);
        inst
    }

    /// Drop every derived (non-architectural) cache: cached blocks and the
    /// one-entry page-translation caches. Checkpoint restore calls this —
    /// derived state is never serialized — and it is the honest baseline
    /// for any caller that rebinds the core to fresh RAM contents. The
    /// decode cache survives: it is keyed by raw instruction bits alone
    /// (a pure function) and can never go stale.
    pub fn reset_derived(&mut self) {
        self.block_cache.clear();
        self.fetch_cache = PageCache::default();
        self.load_cache = PageCache::default();
        self.store_cache = PageCache::default();
    }
}

/// What happened during one tick (consumed by the stats machinery for the
/// paper's Figs. 5–7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepEvent {
    /// An instruction retired normally.
    Retired,
    /// An exception was taken to the given level.
    Exception(ExceptionCause, TrapTarget),
    /// An interrupt was taken to the given level.
    Interrupt(InterruptCause, TrapTarget),
    /// Parked in WFI.
    WfiIdle,
}

/// Execute one tick: check interrupts (paper Fig. 2), then fetch, decode,
/// execute; fold any exception into the trap unit.
pub fn step(core: &mut Core, bus: &mut Bus) -> StepEvent {
    // WFI parking.
    if core.hart.wfi {
        if wfi_wakeup(&core.hart) {
            core.hart.wfi = false;
        } else {
            return StepEvent::WfiIdle;
        }
    }

    // "In every tick, the CPU calls CheckInterrupts()" (paper Fig. 2).
    if let Some((cause, target)) = check_interrupts(&core.hart) {
        trap::take_interrupt(&mut core.hart, cause, target);
        return StepEvent::Interrupt(cause, target);
    }

    let pc = core.hart.pc;
    let raw = match fetch(core, bus, pc) {
        Ok(r) => r,
        Err(e) => {
            let target = trap::take_exception(&mut core.hart, &e);
            return StepEvent::Exception(e.cause, target);
        }
    };
    let inst = core.decode_cached(raw);
    match execute(core, bus, &inst) {
        Ok(next_pc) => {
            core.hart.pc = next_pc;
            core.hart.csr.minstret = core.hart.csr.minstret.wrapping_add(1);
            StepEvent::Retired
        }
        Err(e) => {
            let target = trap::take_exception(&mut core.hart, &e);
            StepEvent::Exception(e.cause, target)
        }
    }
}

fn fetch(core: &mut Core, bus: &mut Bus, pc: u64) -> Result<u32, Exception> {
    if pc & 3 != 0 {
        return Err(Exception::new(ExceptionCause::InstAddrMisaligned, pc));
    }
    if let Some(t) = &mut core.trace {
        t.push(pc, crate::trace::KIND_FETCH);
    }
    let pa = fetch_translate(core, bus, pc)?;
    bus.read(pa, 4)
        .map(|v| v as u32)
        .map_err(|_| Exception::new(ExceptionCause::InstAccessFault, pc))
}

/// Instruction-fetch translation only (no byte read, no trace push): the
/// shared fetch-page fast path of both engines. The per-tick engine calls
/// it once per instruction through [`fetch`]; the block engine once per
/// block dispatch (the amortization §Perf is about). SUM/MXR don't affect
/// execute checks, so the page-cache key uses 0 there.
pub(crate) fn fetch_translate(core: &mut Core, bus: &mut Bus, pc: u64) -> Result<u64, Exception> {
    let vpn = pc >> 12;
    let prv = core.hart.prv.bits() as u8;
    let virt = core.hart.virt;
    let gen = core.tlb.generation();
    if core.fetch_cache.hit(vpn, prv, virt, 0, gen) {
        return Ok(core.fetch_cache.pa_page | (pc & 0xfff));
    }
    let ctx = TranslateCtx {
        csr: &core.hart.csr,
        prv: core.hart.prv,
        virt,
        access: Access::Execute,
        flags: XlateFlags::default(),
        tinst: 0, // fetch guest-page faults report tinst = 0 (paper §3.4)
    };
    let pa = mmu::translate(&mut core.tlb, &mut core.mmu_stats, bus, &ctx, pc)?;
    core.fetch_cache =
        PageCache { valid: true, vpn, pa_page: pa & !0xfff, prv, virt, sum_mxr: 0, gen };
    Ok(pa)
}

/// Status bits that participate in data-access permission checks and thus
/// in the page-cache key (mstatus.SUM/MXR + vsstatus.SUM/MXR when V=1).
#[inline]
fn sum_mxr_key(hart: &Hart, virt: bool) -> u8 {
    let m = ((hart.csr.mstatus >> 18) & 3) as u8;
    if virt {
        m | (((hart.csr.vsstatus >> 18) & 3) as u8) << 2
    } else {
        m
    }
}

/// Resolve the effective (privilege, V) for a *data* access: HLV/HSV force
/// virtualization with hstatus.SPVP privilege; otherwise mstatus.MPRV
/// substitutes MPP/MPV while in M-mode.
fn data_access_env(hart: &Hart, flags: &XlateFlags) -> (PrivLevel, bool) {
    if flags.forced_virt {
        let prv = if hart.csr.hstatus & hstatus::SPVP != 0 {
            PrivLevel::Supervisor
        } else {
            PrivLevel::User
        };
        return (prv, true);
    }
    let st = hart.csr.mstatus;
    if hart.prv == PrivLevel::Machine && st & mstatus::MPRV != 0 {
        let mpp = PrivLevel::from_bits((st & mstatus::MPP_MASK) >> mstatus::MPP_SHIFT);
        let mpv = st & mstatus::MPV != 0 && mpp != PrivLevel::Machine;
        return (mpp, hart.csr.h_enabled && mpv);
    }
    (hart.prv, hart.virt)
}

fn mem_read(core: &mut Core, bus: &mut Bus, va: u64, size: u64, flags: XlateFlags, tinst: u64) -> Result<u64, Exception> {
    // Misaligned accesses are fine within a page; page-crossers trap.
    if (va & 0xfff) + size > 0x1000 && va % size != 0 {
        return Err(Exception::new(ExceptionCause::LoadAddrMisaligned, va));
    }
    if let Some(t) = &mut core.trace {
        t.push(va, crate::trace::KIND_LOAD);
    }
    let (prv, virt) = data_access_env(&core.hart, &flags);
    // Load-page fast path (bypassed for HLV/HLVX, which carry their own
    // translation context).
    let vpn = va >> 12;
    let prv_b = prv.bits() as u8;
    let key = sum_mxr_key(&core.hart, virt);
    let gen = core.tlb.generation();
    if !flags.forced_virt && core.load_cache.hit(vpn, prv_b, virt, key, gen) {
        let pa = core.load_cache.pa_page | (va & 0xfff);
        return bus.read(pa, size).map_err(|_| Exception::new(ExceptionCause::LoadAccessFault, va));
    }
    let ctx = TranslateCtx { csr: &core.hart.csr, prv, virt, access: Access::Read, flags, tinst };
    let pa = mmu::translate(&mut core.tlb, &mut core.mmu_stats, bus, &ctx, va)?;
    if !flags.forced_virt {
        core.load_cache =
            PageCache { valid: true, vpn, pa_page: pa & !0xfff, prv: prv_b, virt, sum_mxr: key, gen };
    }
    bus.read(pa, size).map_err(|_| Exception::new(ExceptionCause::LoadAccessFault, va))
}

fn mem_write(core: &mut Core, bus: &mut Bus, va: u64, size: u64, val: u64, flags: XlateFlags, tinst: u64) -> Result<(), Exception> {
    if (va & 0xfff) + size > 0x1000 && va % size != 0 {
        return Err(Exception::new(ExceptionCause::StoreAddrMisaligned, va));
    }
    if let Some(t) = &mut core.trace {
        t.push(va, crate::trace::KIND_STORE);
    }
    let (prv, virt) = data_access_env(&core.hart, &flags);
    let vpn = va >> 12;
    let prv_b = prv.bits() as u8;
    let key = sum_mxr_key(&core.hart, virt);
    let gen = core.tlb.generation();
    if !flags.forced_virt && core.store_cache.hit(vpn, prv_b, virt, key, gen) {
        let pa = core.store_cache.pa_page | (va & 0xfff);
        return bus
            .write(pa, size, val)
            .map_err(|_| Exception::new(ExceptionCause::StoreAccessFault, va));
    }
    let ctx = TranslateCtx { csr: &core.hart.csr, prv, virt, access: Access::Write, flags, tinst };
    let pa = mmu::translate(&mut core.tlb, &mut core.mmu_stats, bus, &ctx, va)?;
    if !flags.forced_virt {
        core.store_cache =
            PageCache { valid: true, vpn, pa_page: pa & !0xfff, prv: prv_b, virt, sum_mxr: key, gen };
    }
    bus.write(pa, size, val).map_err(|_| Exception::new(ExceptionCause::StoreAccessFault, va))
}

/// Translate for an AMO/SC (write access), returning the PA.
fn amo_translate(core: &mut Core, bus: &mut Bus, va: u64, size: u64, tinst: u64) -> Result<u64, Exception> {
    if va % size != 0 {
        return Err(Exception::new(ExceptionCause::StoreAddrMisaligned, va));
    }
    let (prv, virt) = data_access_env(&core.hart, &XlateFlags::default());
    let ctx = TranslateCtx {
        csr: &core.hart.csr,
        prv,
        virt,
        access: Access::Write,
        flags: XlateFlags::default(),
        tinst,
    };
    mmu::translate(&mut core.tlb, &mut core.mmu_stats, bus, &ctx, va)
}

#[inline]
fn sext32(v: u64) -> u64 {
    v as u32 as i32 as i64 as u64
}

/// Execute a decoded instruction; returns the next PC.
pub fn execute(core: &mut Core, bus: &mut Bus, inst: &Inst) -> Result<u64, Exception> {
    use Op::*;
    let hart = &mut core.hart;
    let pc = hart.pc;
    let next = pc.wrapping_add(4);
    let rs1 = hart.reg(inst.rs1);
    let rs2 = hart.reg(inst.rs2);
    let imm = inst.imm as u64;

    match inst.op {
        Lui => hart.set_reg(inst.rd, imm),
        Auipc => hart.set_reg(inst.rd, pc.wrapping_add(imm)),
        Jal => {
            let target = pc.wrapping_add(imm);
            if target & 3 != 0 {
                return Err(Exception::new(ExceptionCause::InstAddrMisaligned, target));
            }
            hart.set_reg(inst.rd, next);
            return Ok(target);
        }
        Jalr => {
            let target = rs1.wrapping_add(imm) & !1;
            if target & 3 != 0 {
                return Err(Exception::new(ExceptionCause::InstAddrMisaligned, target));
            }
            hart.set_reg(inst.rd, next);
            return Ok(target);
        }
        Beq | Bne | Blt | Bge | Bltu | Bgeu => {
            let take = match inst.op {
                Beq => rs1 == rs2,
                Bne => rs1 != rs2,
                Blt => (rs1 as i64) < (rs2 as i64),
                Bge => (rs1 as i64) >= (rs2 as i64),
                Bltu => rs1 < rs2,
                _ => rs1 >= rs2,
            };
            if take {
                let target = pc.wrapping_add(imm);
                if target & 3 != 0 {
                    return Err(Exception::new(ExceptionCause::InstAddrMisaligned, target));
                }
                return Ok(target);
            }
        }
        Lb | Lh | Lw | Ld | Lbu | Lhu | Lwu => {
            let size = inst.op.access_size();
            let va = rs1.wrapping_add(imm);
            let v = mem_read(core, bus, va, size, XlateFlags::default(), inst.transformed_for_tinst())?;
            let v = match inst.op {
                Lb => v as u8 as i8 as i64 as u64,
                Lh => v as u16 as i16 as i64 as u64,
                Lw => sext32(v),
                _ => v,
            };
            core.hart.set_reg(inst.rd, v);
            return Ok(next);
        }
        Sb | Sh | Sw | Sd => {
            let size = inst.op.access_size();
            let va = rs1.wrapping_add(imm);
            mem_write(core, bus, va, size, rs2, XlateFlags::default(), inst.transformed_for_tinst())?;
            // A store invalidates any matching reservation.
            core.hart.reservation = None;
            return Ok(next);
        }
        Addi => hart.set_reg(inst.rd, rs1.wrapping_add(imm)),
        Slti => hart.set_reg(inst.rd, ((rs1 as i64) < (imm as i64)) as u64),
        Sltiu => hart.set_reg(inst.rd, (rs1 < imm) as u64),
        Xori => hart.set_reg(inst.rd, rs1 ^ imm),
        Ori => hart.set_reg(inst.rd, rs1 | imm),
        Andi => hart.set_reg(inst.rd, rs1 & imm),
        Slli => hart.set_reg(inst.rd, rs1 << (imm & 63)),
        Srli => hart.set_reg(inst.rd, rs1 >> (imm & 63)),
        Srai => hart.set_reg(inst.rd, ((rs1 as i64) >> (imm & 63)) as u64),
        Add => hart.set_reg(inst.rd, rs1.wrapping_add(rs2)),
        Sub => hart.set_reg(inst.rd, rs1.wrapping_sub(rs2)),
        Sll => hart.set_reg(inst.rd, rs1 << (rs2 & 63)),
        Slt => hart.set_reg(inst.rd, ((rs1 as i64) < (rs2 as i64)) as u64),
        Sltu => hart.set_reg(inst.rd, (rs1 < rs2) as u64),
        Xor => hart.set_reg(inst.rd, rs1 ^ rs2),
        Srl => hart.set_reg(inst.rd, rs1 >> (rs2 & 63)),
        Sra => hart.set_reg(inst.rd, ((rs1 as i64) >> (rs2 & 63)) as u64),
        Or => hart.set_reg(inst.rd, rs1 | rs2),
        And => hart.set_reg(inst.rd, rs1 & rs2),
        Addiw => hart.set_reg(inst.rd, sext32(rs1.wrapping_add(imm))),
        Slliw => hart.set_reg(inst.rd, sext32(rs1 << (imm & 31))),
        Srliw => hart.set_reg(inst.rd, sext32((rs1 as u32 >> (imm & 31)) as u64)),
        Sraiw => hart.set_reg(inst.rd, ((rs1 as i32) >> (imm & 31)) as i64 as u64),
        Addw => hart.set_reg(inst.rd, sext32(rs1.wrapping_add(rs2))),
        Subw => hart.set_reg(inst.rd, sext32(rs1.wrapping_sub(rs2))),
        Sllw => hart.set_reg(inst.rd, sext32(rs1 << (rs2 & 31))),
        Srlw => hart.set_reg(inst.rd, sext32((rs1 as u32 >> (rs2 & 31)) as u64)),
        Sraw => hart.set_reg(inst.rd, ((rs1 as i32) >> (rs2 & 31)) as i64 as u64),
        Mul => hart.set_reg(inst.rd, rs1.wrapping_mul(rs2)),
        Mulh => hart.set_reg(inst.rd, ((rs1 as i64 as i128 * rs2 as i64 as i128) >> 64) as u64),
        Mulhsu => hart.set_reg(inst.rd, ((rs1 as i64 as i128 * rs2 as u128 as i128) >> 64) as u64),
        Mulhu => hart.set_reg(inst.rd, ((rs1 as u128 * rs2 as u128) >> 64) as u64),
        Div => {
            let v = if rs2 == 0 {
                u64::MAX
            } else if rs1 as i64 == i64::MIN && rs2 as i64 == -1 {
                rs1
            } else {
                ((rs1 as i64) / (rs2 as i64)) as u64
            };
            hart.set_reg(inst.rd, v);
        }
        Divu => hart.set_reg(inst.rd, if rs2 == 0 { u64::MAX } else { rs1 / rs2 }),
        Rem => {
            let v = if rs2 == 0 {
                rs1
            } else if rs1 as i64 == i64::MIN && rs2 as i64 == -1 {
                0
            } else {
                ((rs1 as i64) % (rs2 as i64)) as u64
            };
            hart.set_reg(inst.rd, v);
        }
        Remu => hart.set_reg(inst.rd, if rs2 == 0 { rs1 } else { rs1 % rs2 }),
        Mulw => hart.set_reg(inst.rd, sext32(rs1.wrapping_mul(rs2))),
        Divw => {
            let a = rs1 as i32;
            let b = rs2 as i32;
            let v = if b == 0 {
                -1i64 as u64
            } else if a == i32::MIN && b == -1 {
                a as i64 as u64
            } else {
                (a / b) as i64 as u64
            };
            hart.set_reg(inst.rd, v);
        }
        Divuw => {
            let a = rs1 as u32;
            let b = rs2 as u32;
            let v = if b == 0 { u64::MAX } else { sext32((a / b) as u64) };
            hart.set_reg(inst.rd, v);
        }
        Remw => {
            let a = rs1 as i32;
            let b = rs2 as i32;
            let v = if b == 0 {
                a as i64 as u64
            } else if a == i32::MIN && b == -1 {
                0
            } else {
                (a % b) as i64 as u64
            };
            hart.set_reg(inst.rd, v);
        }
        Remuw => {
            let a = rs1 as u32;
            let b = rs2 as u32;
            let v = if b == 0 { sext32(a as u64) } else { sext32((a % b) as u64) };
            hart.set_reg(inst.rd, v);
        }
        Fence | FenceI => {}
        Ecall => {
            let cause = match (hart.prv, hart.virt) {
                (PrivLevel::User, _) => ExceptionCause::EcallFromU,
                (PrivLevel::Supervisor, false) => ExceptionCause::EcallFromS,
                (PrivLevel::Supervisor, true) => ExceptionCause::EcallFromVS,
                (PrivLevel::Machine, _) => ExceptionCause::EcallFromM,
            };
            return Err(Exception::new(cause, 0));
        }
        Ebreak => return Err(Exception::new(ExceptionCause::Breakpoint, pc)),
        Mret => {
            if hart.prv != PrivLevel::Machine {
                return Err(Exception::illegal(inst.raw));
            }
            trap::mret(hart);
            return Ok(hart.pc);
        }
        Sret => {
            match (hart.prv, hart.virt) {
                (PrivLevel::Machine, _) => {
                    trap::sret_hs(hart);
                }
                (PrivLevel::Supervisor, false) => {
                    if hart.csr.mstatus & mstatus::TSR != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                    trap::sret_hs(hart);
                }
                (PrivLevel::Supervisor, true) => {
                    // Paper §3.4 virtual_instruction tests: sret from VS
                    // with hstatus.VTSR set → virtual-instruction fault.
                    if hart.csr.hstatus & hstatus::VTSR != 0 {
                        return Err(Exception::virtual_inst(inst.raw));
                    }
                    trap::sret_vs(hart);
                }
                (PrivLevel::User, false) => return Err(Exception::illegal(inst.raw)),
                (PrivLevel::User, true) => return Err(Exception::virtual_inst(inst.raw)),
            }
            return Ok(hart.pc);
        }
        Wfi => {
            match (hart.prv, hart.virt) {
                (PrivLevel::Machine, _) => {}
                (PrivLevel::Supervisor, false) => {
                    if hart.csr.mstatus & mstatus::TW != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                }
                (PrivLevel::Supervisor, true) => {
                    // wfi_exception_tests: TW → illegal; else VTW → virtual.
                    if hart.csr.mstatus & mstatus::TW != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                    if hart.csr.hstatus & hstatus::VTW != 0 {
                        return Err(Exception::virtual_inst(inst.raw));
                    }
                }
                (PrivLevel::User, false) => {
                    if hart.csr.mstatus & mstatus::TW != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                }
                (PrivLevel::User, true) => {
                    if hart.csr.mstatus & mstatus::TW != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                    return Err(Exception::virtual_inst(inst.raw));
                }
            }
            if !wfi_wakeup(hart) {
                hart.wfi = true;
            }
        }
        SfenceVma => {
            match (hart.prv, hart.virt) {
                (PrivLevel::Machine, _) => {}
                (PrivLevel::Supervisor, false) => {
                    if hart.csr.mstatus & mstatus::TVM != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                }
                (PrivLevel::Supervisor, true) => {
                    if hart.csr.hstatus & hstatus::VTVM != 0 {
                        return Err(Exception::virtual_inst(inst.raw));
                    }
                    // VS-mode sfence affects the guest's VS-stage entries.
                    let vmid = atp::vmid(hart.csr.hgatp) as u16;
                    let va = if inst.rs1 != 0 { Some(rs1) } else { None };
                    let asid = if inst.rs2 != 0 { Some(rs2 as u16) } else { None };
                    core.tlb.fence_vvma(vmid, va, asid);
                    core.mmu_stats.flushes += 1;
                    return Ok(next);
                }
                (PrivLevel::User, false) => return Err(Exception::illegal(inst.raw)),
                (PrivLevel::User, true) => return Err(Exception::virtual_inst(inst.raw)),
            }
            let va = if inst.rs1 != 0 { Some(rs1) } else { None };
            let asid = if inst.rs2 != 0 { Some(rs2 as u16) } else { None };
            core.tlb.fence_vma(va, asid);
            core.mmu_stats.flushes += 1;
            return Ok(next);
        }
        HfenceVvma | HfenceGvma => {
            if !hart.csr.h_enabled {
                return Err(Exception::illegal(inst.raw));
            }
            match (hart.prv, hart.virt) {
                (PrivLevel::Machine, _) => {}
                (PrivLevel::Supervisor, false) => {
                    if inst.op == HfenceGvma && hart.csr.mstatus & mstatus::TVM != 0 {
                        return Err(Exception::illegal(inst.raw));
                    }
                }
                (_, true) => return Err(Exception::virtual_inst(inst.raw)),
                (PrivLevel::User, false) => return Err(Exception::illegal(inst.raw)),
            }
            if inst.op == HfenceVvma {
                // hfence.vvma rs1=vaddr rs2=asid, scoped to current VMID.
                let vmid = atp::vmid(hart.csr.hgatp) as u16;
                let va = if inst.rs1 != 0 { Some(rs1) } else { None };
                let asid = if inst.rs2 != 0 { Some(rs2 as u16) } else { None };
                core.tlb.fence_vvma(vmid, va, asid);
            } else {
                // hfence.gvma rs1=guest-physical>>2 rs2=vmid.
                let gaddr = if inst.rs1 != 0 { Some(rs1 << 2) } else { None };
                let vmid = if inst.rs2 != 0 { Some(rs2 as u16) } else { None };
                core.tlb.fence_gvma(gaddr, vmid);
            }
            core.mmu_stats.flushes += 1;
            return Ok(next);
        }
        HlvB | HlvBu | HlvH | HlvHu | HlvW | HlvWu | HlvD | HlvxHu | HlvxWu => {
            check_hlv_hsv_allowed(hart, inst)?;
            let flags = XlateFlags { forced_virt: true, hlvx: inst.op.is_hlvx(), lr: false };
            let size = inst.op.access_size();
            let v = mem_read(core, bus, rs1, size, flags, inst.transformed_for_tinst())?;
            let v = match inst.op {
                HlvB => v as u8 as i8 as i64 as u64,
                HlvH => v as u16 as i16 as i64 as u64,
                HlvW => sext32(v),
                _ => v, // unsigned variants + D
            };
            core.hart.set_reg(inst.rd, v);
            return Ok(next);
        }
        HsvB | HsvH | HsvW | HsvD => {
            check_hlv_hsv_allowed(hart, inst)?;
            let flags = XlateFlags { forced_virt: true, hlvx: false, lr: false };
            let size = inst.op.access_size();
            mem_write(core, bus, rs1, size, rs2, flags, inst.transformed_for_tinst())?;
            return Ok(next);
        }
        LrW | LrD => {
            let size = inst.op.access_size();
            let va = rs1;
            if va % size != 0 {
                return Err(Exception::new(ExceptionCause::LoadAddrMisaligned, va));
            }
            let flags = XlateFlags { lr: true, ..Default::default() };
            let v = mem_read(core, bus, va, size, flags, inst.transformed_for_tinst())?;
            let v = if inst.op == LrW { sext32(v) } else { v };
            // Reservation on the physical line (re-translate cheap: TLB hot).
            let (prv, virt) = data_access_env(&core.hart, &XlateFlags::default());
            let ctx = TranslateCtx {
                csr: &core.hart.csr,
                prv,
                virt,
                access: Access::Read,
                flags: XlateFlags::default(),
                tinst: 0,
            };
            let pa = mmu::translate(&mut core.tlb, &mut core.mmu_stats, bus, &ctx, va)?;
            core.hart.reservation = Some(pa & !7);
            core.hart.set_reg(inst.rd, v);
            return Ok(next);
        }
        ScW | ScD => {
            let size = inst.op.access_size();
            let pa = amo_translate(core, bus, rs1, size, inst.transformed_for_tinst())?;
            let ok = core.hart.reservation == Some(pa & !7);
            core.hart.reservation = None;
            if ok {
                bus.write(pa, size, rs2)
                    .map_err(|_| Exception::new(ExceptionCause::StoreAccessFault, rs1))?;
                core.hart.set_reg(inst.rd, 0);
            } else {
                core.hart.set_reg(inst.rd, 1);
            }
            return Ok(next);
        }
        AmoSwapW | AmoAddW | AmoXorW | AmoAndW | AmoOrW | AmoMinW | AmoMaxW | AmoMinuW
        | AmoMaxuW | AmoSwapD | AmoAddD | AmoXorD | AmoAndD | AmoOrD | AmoMinD | AmoMaxD
        | AmoMinuD | AmoMaxuD => {
            let size = inst.op.access_size();
            let pa = amo_translate(core, bus, rs1, size, inst.transformed_for_tinst())?;
            let old = bus
                .read(pa, size)
                .map_err(|_| Exception::new(ExceptionCause::StoreAccessFault, rs1))?;
            let old_v = if size == 4 { sext32(old) } else { old };
            let new = amo_op(inst.op, old_v, rs2, size);
            bus.write(pa, size, new)
                .map_err(|_| Exception::new(ExceptionCause::StoreAccessFault, rs1))?;
            core.hart.set_reg(inst.rd, old_v);
            return Ok(next);
        }
        Csrrw | Csrrs | Csrrc | Csrrwi | Csrrsi | Csrrci => {
            return exec_csr(core, inst, rs1, next);
        }
        Flw | Fsw | FaddS | FmulS | FmvWX | FmvXW => {
            return exec_float(core, bus, inst, rs1, rs2, next);
        }
        Illegal => {
            return Err(Exception::illegal(inst.raw));
        }
    }
    Ok(next)
}

/// HLV/HSV legality: V must be 0; allowed from M, HS, or U when
/// hstatus.HU=1. From VS/VU → virtual instruction (paper §3.4,
/// m_and_hs_using_vs_access tests).
fn check_hlv_hsv_allowed(hart: &Hart, inst: &Inst) -> Result<(), Exception> {
    if !hart.csr.h_enabled {
        return Err(Exception::illegal(inst.raw));
    }
    if hart.virt {
        return Err(Exception::virtual_inst(inst.raw));
    }
    match hart.prv {
        PrivLevel::Machine | PrivLevel::Supervisor => Ok(()),
        PrivLevel::User => {
            if hart.csr.hstatus & hstatus::HU != 0 {
                Ok(())
            } else {
                Err(Exception::illegal(inst.raw))
            }
        }
    }
}

fn amo_op(op: Op, old: u64, rs2: u64, size: u64) -> u64 {
    use Op::*;
    let (a32, b32) = (old as i32, rs2 as i32);
    match op {
        AmoSwapW | AmoSwapD => rs2,
        AmoAddW => a32.wrapping_add(b32) as u64,
        AmoAddD => old.wrapping_add(rs2),
        AmoXorW | AmoXorD => old ^ rs2,
        AmoAndW | AmoAndD => old & rs2,
        AmoOrW | AmoOrD => old | rs2,
        AmoMinW => a32.min(b32) as u64,
        AmoMaxW => a32.max(b32) as u64,
        AmoMinuW => (old as u32).min(rs2 as u32) as u64,
        AmoMaxuW => (old as u32).max(rs2 as u32) as u64,
        AmoMinD => (old as i64).min(rs2 as i64) as u64,
        AmoMaxD => (old as i64).max(rs2 as i64) as u64,
        AmoMinuD => old.min(rs2),
        AmoMaxuD => old.max(rs2),
        _ => unreachable!("non-AMO op {op:?} size {size}"),
    }
}

fn exec_csr(core: &mut Core, inst: &Inst, rs1: u64, next: u64) -> Result<u64, Exception> {
    use Op::*;
    let hart = &mut core.hart;
    let prv = hart.prv;
    let virt = hart.virt;
    let addr = inst.csr;

    // TVM/VTVM gating for satp (and the VS-redirected vsatp).
    if addr == csrdef::CSR_SATP {
        if prv == PrivLevel::Supervisor && !virt && hart.csr.mstatus & mstatus::TVM != 0 {
            return Err(Exception::illegal(inst.raw));
        }
        if prv == PrivLevel::Supervisor && virt && hart.csr.hstatus & hstatus::VTVM != 0 {
            return Err(Exception::virtual_inst(inst.raw));
        }
    }

    let map_err = |e: CsrError, raw: u32| match e {
        CsrError::Illegal => Exception::illegal(raw),
        CsrError::Virtual => Exception::virtual_inst(raw),
    };

    let old = hart.csr.read(addr, prv, virt).map_err(|e| map_err(e, inst.raw))?;
    let src = match inst.op {
        Csrrw | Csrrs | Csrrc => rs1,
        _ => inst.imm as u64, // zimm
    };
    let (do_write, new) = match inst.op {
        Csrrw | Csrrwi => (true, src),
        Csrrs | Csrrsi => (inst.rs1 != 0, old | src),
        _ => (inst.rs1 != 0, old & !src),
    };
    if do_write {
        hart.csr.write(addr, new, prv, virt).map_err(|e| map_err(e, inst.raw))?;
        // Writing satp/vsatp/hgatp changes the address space; flush
        // conservatively (software also issues fences, but this keeps the
        // TLB coherent for flushless firmware).
        if matches!(addr, csrdef::CSR_SATP | csrdef::CSR_VSATP | csrdef::CSR_HGATP) {
            core.tlb.flush_all();
        }
    }
    core.hart.set_reg(inst.rd, old);
    Ok(next)
}

/// Minimal F subset with the FS-field gating of §3.5 (challenge 2): when
/// V=1, vsstatus.FS is checked in addition to mstatus.FS.
fn exec_float(
    core: &mut Core,
    bus: &mut Bus,
    inst: &Inst,
    rs1: u64,
    rs2: u64,
    next: u64,
) -> Result<u64, Exception> {
    use Op::*;
    let hart = &core.hart;
    if hart.csr.mstatus & mstatus::FS_MASK == mstatus::FS_OFF {
        return Err(Exception::illegal(inst.raw));
    }
    if hart.virt && hart.csr.vsstatus & mstatus::FS_MASK == mstatus::FS_OFF {
        // Guest FPU disabled by vsstatus: virtual-instruction fault so the
        // hypervisor can lazily enable/emulate.
        return Err(Exception::virtual_inst(inst.raw));
    }
    match inst.op {
        Flw => {
            let va = rs1.wrapping_add(inst.imm as u64);
            let v = mem_read(core, bus, va, 4, XlateFlags::default(), inst.transformed_for_tinst())?;
            core.hart.fregs[inst.rd as usize] = v | 0xffff_ffff_0000_0000; // NaN-boxed
        }
        Fsw => {
            let va = rs1.wrapping_add(inst.imm as u64);
            let v = core.hart.fregs[inst.rs2 as usize] as u32 as u64;
            mem_write(core, bus, va, 4, v, XlateFlags::default(), inst.transformed_for_tinst())?;
        }
        FaddS => {
            let a = f32::from_bits(core.hart.fregs[inst.rs1 as usize] as u32);
            let b = f32::from_bits(core.hart.fregs[inst.rs2 as usize] as u32);
            core.hart.fregs[inst.rd as usize] =
                (a + b).to_bits() as u64 | 0xffff_ffff_0000_0000;
        }
        FmulS => {
            let a = f32::from_bits(core.hart.fregs[inst.rs1 as usize] as u32);
            let b = f32::from_bits(core.hart.fregs[inst.rs2 as usize] as u32);
            core.hart.fregs[inst.rd as usize] =
                (a * b).to_bits() as u64 | 0xffff_ffff_0000_0000;
        }
        FmvWX => {
            core.hart.fregs[inst.rd as usize] = (rs1 as u32) as u64 | 0xffff_ffff_0000_0000;
        }
        FmvXW => {
            let v = sext32(core.hart.fregs[inst.rs1 as usize] & 0xffff_ffff);
            core.hart.set_reg(inst.rd, v);
            let _ = rs2;
        }
        _ => unreachable!(),
    }
    let virt = core.hart.virt;
    core.hart.csr.set_fs_dirty(virt);
    Ok(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::RAM_BASE;

    fn world() -> (Core, Bus) {
        let mut core = Core::new(true);
        core.hart.pc = RAM_BASE;
        core.hart.csr.mstatus |= mstatus::FS_INITIAL;
        core.hart.csr.vsstatus |= mstatus::FS_INITIAL;
        (core, Bus::new(4 << 20))
    }

    fn run_one(core: &mut Core, bus: &mut Bus, words: &[u32]) -> StepEvent {
        let mut bytes = Vec::new();
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bus.load_image(core.hart.pc, &bytes).unwrap();
        step(core, bus)
    }

    fn asm_addi(rd: u32, rs1: u32, imm: i32) -> u32 {
        ((imm as u32 & 0xfff) << 20) | (rs1 << 15) | (rd << 7) | 0b0010011
    }

    #[test]
    fn basic_arith_and_pc_advance() {
        let (mut core, mut bus) = world();
        core.hart.regs[5] = 40;
        let ev = run_one(&mut core, &mut bus, &[asm_addi(6, 5, 2)]);
        assert_eq!(ev, StepEvent::Retired);
        assert_eq!(core.hart.regs[6], 42);
        assert_eq!(core.hart.pc, RAM_BASE + 4);
        assert_eq!(core.hart.csr.minstret, 1);
    }

    #[test]
    fn load_store_round_trip() {
        let (mut core, mut bus) = world();
        // sd x5, 64(x10); pc advances; then ld x6, 64(x10)
        core.hart.regs[5] = 0xdead_beef_cafe_f00d;
        core.hart.regs[10] = RAM_BASE + 0x1000;
        let sd = (0 << 25) | (5 << 20) | (10 << 15) | (0b011 << 12) | ((64 & 0x1f) << 7) | 0b0100011
            | ((64 >> 5) << 25);
        let ld = (64 << 20) | (10 << 15) | (0b011 << 12) | (6 << 7) | 0b0000011;
        assert_eq!(run_one(&mut core, &mut bus, &[sd, ld]), StepEvent::Retired);
        assert_eq!(step(&mut core, &mut bus), StepEvent::Retired);
        assert_eq!(core.hart.regs[6], 0xdead_beef_cafe_f00d);
    }

    #[test]
    fn ecall_cause_depends_on_mode() {
        for (prv, virt, want) in [
            (PrivLevel::Machine, false, ExceptionCause::EcallFromM),
            (PrivLevel::Supervisor, false, ExceptionCause::EcallFromS),
            (PrivLevel::Supervisor, true, ExceptionCause::EcallFromVS),
            (PrivLevel::User, true, ExceptionCause::EcallFromU),
        ] {
            let (mut core, mut bus) = world();
            core.hart.prv = prv;
            core.hart.virt = virt;
            // Stay bare-translation: M-mode fetch is bare; for S/VS we keep
            // satp/vsatp/hgatp = 0 (BARE everywhere) so fetch works.
            match run_one(&mut core, &mut bus, &[0x0000_0073]) {
                StepEvent::Exception(cause, _) => assert_eq!(cause, want),
                e => panic!("expected exception, got {e:?}"),
            }
        }
    }

    #[test]
    fn illegal_instruction_sets_mtval() {
        let (mut core, mut bus) = world();
        match run_one(&mut core, &mut bus, &[0xffff_ffff]) {
            StepEvent::Exception(cause, TrapTarget::M) => {
                assert_eq!(cause, ExceptionCause::IllegalInst);
                assert_eq!(core.hart.csr.mtval, 0xffff_ffff);
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn wfi_parks_until_interrupt() {
        let (mut core, mut bus) = world();
        assert_eq!(run_one(&mut core, &mut bus, &[0x1050_0073]), StepEvent::Retired);
        assert!(core.hart.wfi);
        assert_eq!(step(&mut core, &mut bus), StepEvent::WfiIdle);
        // Raise MTIP+MTIE → wakes, then takes the interrupt.
        core.hart.csr.mip |= crate::isa::csr::irq::MTIP;
        core.hart.csr.mie |= crate::isa::csr::irq::MTIP;
        core.hart.csr.mstatus |= mstatus::MIE;
        match step(&mut core, &mut bus) {
            StepEvent::Interrupt(InterruptCause::MachineTimer, TrapTarget::M) => {}
            e => panic!("{e:?}"),
        }
        assert!(!core.hart.wfi);
    }

    #[test]
    fn wfi_virtual_instruction_when_vtw() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        core.hart.virt = true;
        core.hart.csr.hstatus |= hstatus::VTW;
        match run_one(&mut core, &mut bus, &[0x1050_0073]) {
            StepEvent::Exception(ExceptionCause::VirtualInstruction, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn amo_add() {
        let (mut core, mut bus) = world();
        core.hart.regs[6] = RAM_BASE + 0x2000;
        core.hart.regs[7] = 5;
        bus.write(RAM_BASE + 0x2000, 4, 37).unwrap();
        // amoadd.w x5, x7, (x6)
        let raw = (0b0000000 << 25) | (7 << 20) | (6 << 15) | (0b010 << 12) | (5 << 7) | 0b0101111;
        assert_eq!(run_one(&mut core, &mut bus, &[raw]), StepEvent::Retired);
        assert_eq!(core.hart.regs[5], 37);
        assert_eq!(bus.read(RAM_BASE + 0x2000, 4).unwrap(), 42);
    }

    #[test]
    fn lr_sc_success_and_failure() {
        let (mut core, mut bus) = world();
        core.hart.regs[6] = RAM_BASE + 0x2000;
        core.hart.regs[7] = 99;
        bus.write(RAM_BASE + 0x2000, 8, 1).unwrap();
        let lr = (0b0001000 << 25) | (6 << 15) | (0b011 << 12) | (5 << 7) | 0b0101111; // lr.d x5,(x6)
        let sc = (0b0001100 << 25) | (7 << 20) | (6 << 15) | (0b011 << 12) | (8 << 7) | 0b0101111; // sc.d x8,x7,(x6)
        assert_eq!(run_one(&mut core, &mut bus, &[lr, sc, sc]), StepEvent::Retired);
        assert_eq!(core.hart.regs[5], 1);
        assert_eq!(step(&mut core, &mut bus), StepEvent::Retired);
        assert_eq!(core.hart.regs[8], 0, "sc succeeds");
        assert_eq!(bus.read(RAM_BASE + 0x2000, 8).unwrap(), 99);
        assert_eq!(step(&mut core, &mut bus), StepEvent::Retired);
        assert_eq!(core.hart.regs[8], 1, "second sc fails (no reservation)");
    }

    #[test]
    fn csrrw_reads_old_writes_new() {
        let (mut core, mut bus) = world();
        core.hart.csr.mscratch = 7;
        core.hart.regs[5] = 123;
        let raw = ((csrdef::CSR_MSCRATCH as u32) << 20) | (5 << 15) | (0b001 << 12) | (6 << 7) | 0b1110011;
        assert_eq!(run_one(&mut core, &mut bus, &[raw]), StepEvent::Retired);
        assert_eq!(core.hart.regs[6], 7);
        assert_eq!(core.hart.csr.mscratch, 123);
    }

    #[test]
    fn csrrs_x0_does_not_write() {
        let (mut core, mut bus) = world();
        // csrrs x5, mhartid, x0 — mhartid is RO; must not trap.
        let raw = ((csrdef::CSR_MHARTID as u32) << 20) | (0b010 << 12) | (5 << 7) | 0b1110011;
        assert_eq!(run_one(&mut core, &mut bus, &[raw]), StepEvent::Retired);
    }

    #[test]
    fn hlv_from_vs_is_virtual_instruction() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        core.hart.virt = true;
        // hlv.w x5, (x6)
        let raw = (0b0110100 << 25) | (6 << 15) | (0b100 << 12) | (5 << 7) | 0b1110011;
        match run_one(&mut core, &mut bus, &[raw]) {
            StepEvent::Exception(ExceptionCause::VirtualInstruction, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn hlv_reads_guest_memory_bare() {
        // With vsatp/hgatp BARE, HLV from M reads the "guest" address
        // directly.
        let (mut core, mut bus) = world();
        bus.write(RAM_BASE + 0x3000, 4, 0x1234_5678).unwrap();
        core.hart.regs[6] = RAM_BASE + 0x3000;
        let raw = (0b0110100 << 25) | (6 << 15) | (0b100 << 12) | (5 << 7) | 0b1110011;
        assert_eq!(run_one(&mut core, &mut bus, &[raw]), StepEvent::Retired);
        assert_eq!(core.hart.regs[5], 0x1234_5678);
    }

    #[test]
    fn hlv_from_u_requires_hu() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::User;
        let raw = (0b0110100 << 25) | (6 << 15) | (0b100 << 12) | (5 << 7) | 0b1110011;
        core.hart.regs[6] = RAM_BASE + 0x3000;
        match run_one(&mut core, &mut bus, &[raw]) {
            StepEvent::Exception(ExceptionCause::IllegalInst, _) => {}
            e => panic!("{e:?}"),
        }
        // With hstatus.HU it executes.
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::User;
        core.hart.csr.hstatus |= hstatus::HU;
        core.hart.regs[6] = RAM_BASE + 0x3000;
        bus.write(RAM_BASE + 0x3000, 4, 77).unwrap();
        assert_eq!(run_one(&mut core, &mut bus, &[raw]), StepEvent::Retired);
        assert_eq!(core.hart.regs[5], 77);
    }

    #[test]
    fn float_gated_by_vsstatus_fs() {
        // §3.5 challenge 2.
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        core.hart.virt = true;
        core.hart.csr.vsstatus &= !mstatus::FS_MASK; // guest FS off
        let fadd = (0b0000000 << 25) | (2 << 20) | (1 << 15) | (3 << 7) | 0b1010011;
        match run_one(&mut core, &mut bus, &[fadd]) {
            StepEvent::Exception(ExceptionCause::VirtualInstruction, _) => {}
            e => panic!("{e:?}"),
        }
        // Native with mstatus.FS off → plain illegal.
        let (mut core, mut bus) = world();
        core.hart.csr.mstatus &= !mstatus::FS_MASK;
        match run_one(&mut core, &mut bus, &[fadd]) {
            StepEvent::Exception(ExceptionCause::IllegalInst, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn float_add_works_and_dirties_fs() {
        let (mut core, mut bus) = world();
        core.hart.fregs[1] = 2.5f32.to_bits() as u64;
        core.hart.fregs[2] = 0.25f32.to_bits() as u64;
        let fadd = (0b0000000 << 25) | (2 << 20) | (1 << 15) | (3 << 7) | 0b1010011;
        assert_eq!(run_one(&mut core, &mut bus, &[fadd]), StepEvent::Retired);
        assert_eq!(f32::from_bits(core.hart.fregs[3] as u32), 2.75);
        assert_eq!(core.hart.csr.mstatus & mstatus::FS_MASK, mstatus::FS_DIRTY);
    }

    #[test]
    fn mret_from_s_is_illegal() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        match run_one(&mut core, &mut bus, &[0x3020_0073]) {
            StepEvent::Exception(ExceptionCause::IllegalInst, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn sret_vtsr_virtual_instruction() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        core.hart.virt = true;
        core.hart.csr.hstatus |= hstatus::VTSR;
        match run_one(&mut core, &mut bus, &[0x1020_0073]) {
            StepEvent::Exception(ExceptionCause::VirtualInstruction, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn hfence_from_vs_is_virtual() {
        let (mut core, mut bus) = world();
        core.hart.prv = PrivLevel::Supervisor;
        core.hart.virt = true;
        // hfence.vvma x0, x0
        let raw = (0b0010001 << 25) | 0b1110011;
        match run_one(&mut core, &mut bus, &[raw]) {
            StepEvent::Exception(ExceptionCause::VirtualInstruction, _) => {}
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn branch_taken_and_not_taken() {
        let (mut core, mut bus) = world();
        core.hart.regs[1] = 5;
        core.hart.regs[2] = 5;
        // beq x1, x2, +8
        let v = 8u32;
        let beq = (((v >> 12) & 1) << 31)
            | (((v >> 5) & 0x3f) << 25)
            | (2 << 20)
            | (1 << 15)
            | (((v >> 1) & 0xf) << 8)
            | (((v >> 11) & 1) << 7)
            | 0b1100011;
        run_one(&mut core, &mut bus, &[beq]);
        assert_eq!(core.hart.pc, RAM_BASE + 8);
    }

    #[test]
    fn div_rem_edge_cases() {
        let (mut core, mut bus) = world();
        core.hart.regs[1] = 10;
        core.hart.regs[2] = 0;
        // div x3, x1, x2 → -1
        let raw = (1 << 25) | (2 << 20) | (1 << 15) | (0b100 << 12) | (3 << 7) | 0b0110011;
        run_one(&mut core, &mut bus, &[raw]);
        assert_eq!(core.hart.regs[3], u64::MAX);
        // i64::MIN / -1 → i64::MIN (no trap)
        let (mut core, mut bus) = world();
        core.hart.regs[1] = i64::MIN as u64;
        core.hart.regs[2] = -1i64 as u64;
        run_one(&mut core, &mut bus, &[raw]);
        assert_eq!(core.hart.regs[3], i64::MIN as u64);
    }
}
