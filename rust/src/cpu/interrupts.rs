//! Interrupt detection — gem5's `CheckInterrupts()` as the paper's Fig. 2
//! describes it: every tick, read the pending/enable registers and the
//! delegation registers for the current privilege level, pick the highest-
//! priority enabled interrupt and its destination level.

use crate::isa::csr::{irq, mstatus};
use crate::isa::{InterruptCause, PrivLevel};

use super::trap::TrapTarget;
use super::Hart;

/// If an interrupt should be taken now, return (cause, destination).
///
/// Delegation chain (paper Fig. 2): `mideleg` is consulted when the current
/// privilege is below M, `hideleg` when below HS. Destination enables:
/// an interrupt targeting level X is taken iff X is above the current
/// privilege, or X equals it and the level's global IE bit is set.
pub fn check_interrupts(hart: &Hart) -> Option<(InterruptCause, TrapTarget)> {
    let c = &hart.csr;
    let pending = c.mip_read() & c.mie;
    if pending == 0 {
        return None;
    }
    let mideleg = c.mideleg_read();
    let hideleg = c.hideleg;
    let mstatus_v = c.mstatus;
    let prv = hart.prv;
    let virt = hart.virt;

    for &cause in InterruptCause::PRIORITY.iter() {
        let bit = cause.mask();
        if pending & bit == 0 {
            continue;
        }
        let target = if mideleg & bit == 0 {
            TrapTarget::M
        } else if c.h_enabled && bit & irq::VS_MASK != 0 && hideleg & bit != 0 {
            TrapTarget::VS
        } else {
            TrapTarget::HS
        };
        let enabled = match target {
            TrapTarget::M => prv != PrivLevel::Machine || mstatus_v & mstatus::MIE != 0,
            TrapTarget::HS => {
                if prv == PrivLevel::Machine {
                    false
                } else if virt {
                    // HS-level interrupts always preempt the guest.
                    true
                } else {
                    prv == PrivLevel::User || mstatus_v & mstatus::SIE != 0
                }
            }
            TrapTarget::VS => {
                if !virt {
                    false
                } else {
                    prv == PrivLevel::User || c.vsstatus & mstatus::SIE != 0
                }
            }
        };
        if enabled {
            return Some((cause, target));
        }
    }
    None
}

/// WFI wake condition: any pending-and-enabled interrupt, regardless of
/// global IE bits (the privileged spec's resume rule; the paper's
/// wfi_exception_tests also exercise the trapping conditions, handled in
/// execute.rs).
pub fn wfi_wakeup(hart: &Hart) -> bool {
    hart.csr.mip_read() & hart.csr.mie != 0
}

#[cfg(test)]
mod tests {
    use super::*;


    fn hart(prv: PrivLevel, virt: bool) -> Hart {
        let mut h = Hart::new(true);
        h.prv = prv;
        h.virt = virt;
        h
    }

    #[test]
    fn no_pending_no_interrupt() {
        let h = hart(PrivLevel::Machine, false);
        assert_eq!(check_interrupts(&h), None);
    }

    #[test]
    fn machine_timer_needs_mie_in_m_mode() {
        let mut h = hart(PrivLevel::Machine, false);
        h.csr.mip |= irq::MTIP;
        h.csr.mie |= irq::MTIP;
        assert_eq!(check_interrupts(&h), None, "MIE off in M");
        h.csr.mstatus |= mstatus::MIE;
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::MachineTimer, TrapTarget::M))
        );
        // From S, M interrupts fire regardless of MIE.
        let mut h = hart(PrivLevel::Supervisor, false);
        h.csr.mip |= irq::MTIP;
        h.csr.mie |= irq::MTIP;
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::MachineTimer, TrapTarget::M))
        );
    }

    #[test]
    fn mideleg_routes_supervisor_timer_to_hs() {
        let mut h = hart(PrivLevel::Supervisor, false);
        h.csr.mip |= irq::STIP;
        h.csr.mie |= irq::STIP;
        // Not delegated → M (fires since prv < M).
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::SupervisorTimer, TrapTarget::M))
        );
        h.csr.mideleg = irq::STIP;
        // Delegated to HS but SIE off while in HS → masked.
        assert_eq!(check_interrupts(&h), None);
        h.csr.mstatus |= mstatus::SIE;
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::SupervisorTimer, TrapTarget::HS))
        );
    }

    #[test]
    fn vs_interrupt_delegation_chain() {
        // VSTIP pending: mideleg.VSTI is read-only 1 → at least HS.
        let mut h = hart(PrivLevel::Supervisor, true);
        h.csr.mip |= irq::VSTIP;
        h.csr.mie |= irq::VSTIP;
        // hideleg clear → handled at HS; guest is always preemptible.
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::VirtualSupervisorTimer, TrapTarget::HS))
        );
        // hideleg set → VS, gated by vsstatus.SIE.
        h.csr.hideleg = irq::VSTIP;
        assert_eq!(check_interrupts(&h), None, "vsstatus.SIE off");
        h.csr.vsstatus |= mstatus::SIE;
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::VirtualSupervisorTimer, TrapTarget::VS))
        );
    }

    #[test]
    fn vs_interrupts_do_not_preempt_hs() {
        let mut h = hart(PrivLevel::Supervisor, false); // in HS, V=0
        h.csr.mip |= irq::VSTIP;
        h.csr.mie |= irq::VSTIP;
        h.csr.hideleg = irq::VSTIP;
        h.csr.vsstatus |= mstatus::SIE;
        h.csr.mstatus |= mstatus::SIE;
        assert_eq!(check_interrupts(&h), None, "VS-targeted interrupt waits for V=1");
    }

    #[test]
    fn priority_machine_over_supervisor_over_vs() {
        let mut h = hart(PrivLevel::User, true); // VU: everything above fires
        h.csr.mip |= irq::MTIP | irq::STIP | irq::VSTIP;
        h.csr.mie |= irq::MTIP | irq::STIP | irq::VSTIP;
        h.csr.mideleg = irq::STIP;
        h.csr.hideleg = irq::VSTIP;
        let (cause, _) = check_interrupts(&h).unwrap();
        assert_eq!(cause, InterruptCause::MachineTimer);
        h.csr.mip &= !irq::MTIP;
        let (cause, t) = check_interrupts(&h).unwrap();
        assert_eq!(cause, InterruptCause::SupervisorTimer);
        assert_eq!(t, TrapTarget::HS);
        h.csr.mip &= !irq::STIP;
        let (cause, t) = check_interrupts(&h).unwrap();
        assert_eq!(cause, InterruptCause::VirtualSupervisorTimer);
        assert_eq!(t, TrapTarget::VS);
    }

    #[test]
    fn sgei_targets_hs() {
        let mut h = hart(PrivLevel::Supervisor, false);
        h.csr.hgeip = 1 << 3;
        h.csr.hgeie = 1 << 3;
        h.csr.mie |= irq::SGEIP;
        h.csr.mstatus |= mstatus::SIE;
        assert_eq!(
            check_interrupts(&h),
            Some((InterruptCause::SupervisorGuestExternal, TrapTarget::HS))
        );
    }

    #[test]
    fn wfi_wakeup_ignores_global_enables() {
        let mut h = hart(PrivLevel::Machine, false);
        h.csr.mip |= irq::MTIP;
        h.csr.mie |= irq::MTIP;
        // mstatus.MIE off — check_interrupts says no, but WFI wakes.
        assert_eq!(check_interrupts(&h), None);
        assert!(wfi_wakeup(&h));
    }
}
