//! Configuration system: a gem5-style "system configuration" described in
//! a small TOML-subset file (sections, `key = value` with ints, bools and
//! strings) plus programmatic defaults. Dependency-free by design (the
//! offline build has no serde/toml crates — see Cargo.toml).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Full simulator configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    // [machine]
    pub ram_mb: u64,
    pub h_extension: bool,
    pub tlb_sets: u64,
    pub tlb_ways: u64,
    // [workload]
    pub workload: String,
    /// Run the workload inside a VM (hypervisor + guest kernel) instead of
    /// natively.
    pub vm: bool,
    /// Benchmark input-scale knob (MiBench small/large analog).
    pub scale: u64,
    // [sim]
    pub max_ticks: u64,
    /// Simulated harts per scheduled node (H ≥ 1); 1 is the historical
    /// single-hart node.
    pub harts: u64,
    pub uart_echo: bool,
    pub trace_cap: u64,
    /// Execution engine: basic-block translation cache (default) or the
    /// per-tick reference interpreter.
    pub engine: crate::sim::EngineKind,
    // [timing] — the XLA analytics model (E9)
    pub artifacts_dir: String,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            ram_mb: 64,
            h_extension: true,
            tlb_sets: 64,
            tlb_ways: 4,
            workload: "qsort".to_string(),
            vm: false,
            scale: 1,
            max_ticks: 2_000_000_000,
            harts: 1,
            uart_echo: false,
            trace_cap: 8_000_000,
            engine: crate::sim::EngineKind::default(),
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl SimConfig {
    pub fn ram_bytes(&self) -> usize {
        (self.ram_mb as usize) << 20
    }

    /// Parse a TOML-subset config file, overriding defaults.
    pub fn from_str(text: &str) -> Result<SimConfig> {
        let kv = parse_toml_subset(text)?;
        let mut cfg = SimConfig::default();
        for (key, val) in kv {
            match key.as_str() {
                "machine.ram_mb" => cfg.ram_mb = val.int()?,
                "machine.h_extension" => cfg.h_extension = val.boolean()?,
                "machine.tlb_sets" => cfg.tlb_sets = val.int()?,
                "machine.tlb_ways" => cfg.tlb_ways = val.int()?,
                "workload.name" => cfg.workload = val.string()?,
                "workload.vm" => cfg.vm = val.boolean()?,
                "workload.scale" => cfg.scale = val.int()?,
                "sim.max_ticks" => cfg.max_ticks = val.int()?,
                "sim.harts" => cfg.harts = val.int()?,
                "sim.uart_echo" => cfg.uart_echo = val.boolean()?,
                "sim.trace_cap" => cfg.trace_cap = val.int()?,
                "sim.engine" => cfg.engine = val.string()?.parse()?,
                "timing.artifacts_dir" => cfg.artifacts_dir = val.string()?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        if !cfg.tlb_sets.is_power_of_two() {
            bail!("machine.tlb_sets must be a power of two");
        }
        if cfg.harts == 0 {
            bail!("sim.harts must be at least 1");
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        SimConfig::from_str(&text)
    }

    /// Build a machine from this configuration.
    pub fn build_machine(&self) -> crate::sim::Machine {
        let mut m = crate::sim::Machine::new(self.ram_bytes(), self.h_extension);
        m.core.tlb = crate::mmu::Tlb::new(self.tlb_sets as usize, self.tlb_ways as usize);
        m.bus.uart.echo = self.uart_echo;
        m.engine = self.engine;
        m
    }
}

/// A parsed scalar value.
#[derive(Clone, Debug)]
pub enum Value {
    Int(u64),
    Bool(bool),
    Str(String),
}

impl Value {
    fn int(&self) -> Result<u64> {
        match self {
            Value::Int(v) => Ok(*v),
            other => bail!("expected integer, got {other:?}"),
        }
    }
    fn boolean(&self) -> Result<bool> {
        match self {
            Value::Bool(v) => Ok(*v),
            other => bail!("expected bool, got {other:?}"),
        }
    }
    fn string(&self) -> Result<String> {
        match self {
            Value::Str(v) => Ok(v.clone()),
            Value::Int(v) => Ok(v.to_string()),
            other => bail!("expected string, got {other:?}"),
        }
    }
}

/// Parse `[section]` + `key = value` lines into "section.key" → value.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key = value", i + 1);
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let v = v.trim();
        let value = if v == "true" {
            Value::Bool(true)
        } else if v == "false" {
            Value::Bool(false)
        } else if let Some(stripped) = v.strip_prefix("0x") {
            Value::Int(u64::from_str_radix(stripped, 16).with_context(|| format!("line {}", i + 1))?)
        } else if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
            Value::Str(v[1..v.len() - 1].to_string())
        } else if let Ok(n) = v.replace('_', "").parse::<u64>() {
            Value::Int(n)
        } else {
            Value::Str(v.to_string())
        };
        out.insert(key, value);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = SimConfig::default();
        assert!(c.h_extension);
        assert_eq!(c.ram_bytes(), 64 << 20);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
            # benchmark run
            [machine]
            ram_mb = 128
            h_extension = true
            tlb_sets = 32
            tlb_ways = 2

            [workload]
            name = "dijkstra"
            vm = true
            scale = 2

            [sim]
            max_ticks = 50_000_000
            uart_echo = false
        "#;
        let c = SimConfig::from_str(text).unwrap();
        assert_eq!(c.ram_mb, 128);
        assert_eq!(c.workload, "dijkstra");
        assert!(c.vm);
        assert_eq!(c.scale, 2);
        assert_eq!(c.max_ticks, 50_000_000);
        assert_eq!(c.tlb_sets, 32);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(SimConfig::from_str("[machine]\nbogus = 1\n").is_err());
    }

    #[test]
    fn engine_key_parses_and_defaults_to_block() {
        use crate::sim::EngineKind;
        assert_eq!(SimConfig::default().engine, EngineKind::Block);
        let c = SimConfig::from_str("[sim]\nengine = \"tick\"\n").unwrap();
        assert_eq!(c.engine, EngineKind::Tick);
        assert_eq!(c.build_machine().engine, EngineKind::Tick);
        assert!(SimConfig::from_str("[sim]\nengine = \"warp\"\n").is_err());
    }

    #[test]
    fn non_pow2_tlb_rejected() {
        assert!(SimConfig::from_str("[machine]\ntlb_sets = 3\n").is_err());
    }

    #[test]
    fn harts_key_parses_and_rejects_zero() {
        assert_eq!(SimConfig::default().harts, 1);
        let c = SimConfig::from_str("[sim]\nharts = 4\n").unwrap();
        assert_eq!(c.harts, 4);
        assert!(SimConfig::from_str("[sim]\nharts = 0\n").is_err());
    }

    #[test]
    fn hex_and_bare_strings() {
        let kv = parse_toml_subset("[a]\nx = 0x10\ny = hello\n").unwrap();
        assert!(matches!(kv["a.x"], Value::Int(16)));
        assert!(matches!(&kv["a.y"], Value::Str(s) if s == "hello"));
    }

    #[test]
    fn build_machine_applies_tlb_shape() {
        let c = SimConfig { tlb_sets: 16, tlb_ways: 2, ram_mb: 4, ..Default::default() };
        let m = c.build_machine();
        assert_eq!(m.core.tlb.capacity(), 32);
    }
}
