//! Minimal PLIC: enough surface for software to program priorities/enables
//! and for tests to raise external interrupt lines (mip.MEIP / mip.SEIP).
//! Context 0 = M-mode, context 1 = S-mode, as in the virt platform.

const NSRC: usize = 32;

const PRIORITY_BASE: u64 = 0x0;
const PENDING_BASE: u64 = 0x1000;
const ENABLE_BASE: u64 = 0x2000;
const ENABLE_STRIDE: u64 = 0x80;
const CONTEXT_BASE: u64 = 0x20_0000;
const CONTEXT_STRIDE: u64 = 0x1000;

#[derive(Clone, Debug)]
pub struct Plic {
    pub priority: [u32; NSRC],
    pub pending: u32,
    /// enable[context]
    pub enable: [u32; 2],
    pub threshold: [u32; 2],
    /// claimed-but-not-completed per context
    claimed: [u32; 2],
}

impl Plic {
    pub fn new() -> Plic {
        Plic { priority: [0; NSRC], pending: 0, enable: [0; 2], threshold: [0; 2], claimed: [0; 2] }
    }

    /// Raise an interrupt source line (device side / test harness).
    pub fn raise(&mut self, src: u32) {
        if (src as usize) < NSRC && src != 0 {
            self.pending |= 1 << src;
        }
    }

    /// Highest-priority pending+enabled source for a context, above its
    /// threshold.
    fn best(&self, ctx: usize) -> u32 {
        let mut best_src = 0;
        let mut best_prio = self.threshold[ctx];
        let avail = self.pending & self.enable[ctx] & !self.claimed[ctx];
        for s in 1..NSRC as u32 {
            if avail & (1 << s) != 0 && self.priority[s as usize] > best_prio {
                best_prio = self.priority[s as usize];
                best_src = s;
            }
        }
        best_src
    }

    /// External-interrupt line levels: (MEIP, SEIP).
    pub fn irq_lines(&self) -> (bool, bool) {
        (self.best(0) != 0, self.best(1) != 0)
    }

    pub fn read(&self, off: u64) -> u64 {
        match off {
            o if o >= CONTEXT_BASE => {
                let ctx = ((o - CONTEXT_BASE) / CONTEXT_STRIDE) as usize;
                let reg = (o - CONTEXT_BASE) % CONTEXT_STRIDE;
                if ctx >= 2 {
                    return 0;
                }
                match reg {
                    0 => self.threshold[ctx] as u64,
                    4 => {
                        // claim — side-effect-free here; the write path
                        // performs the actual claim (simplification: our
                        // software claims via read then completes via
                        // write, and we latch on read in read_mut below).
                        self.best(ctx) as u64
                    }
                    _ => 0,
                }
            }
            o if (ENABLE_BASE..CONTEXT_BASE).contains(&o) => {
                let ctx = ((o - ENABLE_BASE) / ENABLE_STRIDE) as usize;
                if ctx < 2 {
                    self.enable[ctx] as u64
                } else {
                    0
                }
            }
            o if (PENDING_BASE..ENABLE_BASE).contains(&o) => self.pending as u64,
            o => {
                let src = (o - PRIORITY_BASE) / 4;
                if (src as usize) < NSRC {
                    self.priority[src as usize] as u64
                } else {
                    0
                }
            }
        }
    }

    /// Claim with side effect (used by the bus on claim-register reads is
    /// avoided for simplicity; software uses this via an explicit claim).
    pub fn claim(&mut self, ctx: usize) -> u32 {
        let src = self.best(ctx);
        if src != 0 {
            self.claimed[ctx] |= 1 << src;
            self.pending &= !(1 << src);
        }
        src
    }

    pub fn write(&mut self, off: u64, val: u64) {
        match off {
            o if o >= CONTEXT_BASE => {
                let ctx = ((o - CONTEXT_BASE) / CONTEXT_STRIDE) as usize;
                let reg = (o - CONTEXT_BASE) % CONTEXT_STRIDE;
                if ctx >= 2 {
                    return;
                }
                match reg {
                    0 => self.threshold[ctx] = val as u32,
                    4 => {
                        // complete
                        self.claimed[ctx] &= !(1u32 << (val as u32 & 31));
                    }
                    _ => {}
                }
            }
            o if (ENABLE_BASE..CONTEXT_BASE).contains(&o) => {
                let ctx = ((o - ENABLE_BASE) / ENABLE_STRIDE) as usize;
                if ctx < 2 {
                    self.enable[ctx] = val as u32;
                }
            }
            o if o < PENDING_BASE => {
                let src = o / 4;
                if (src as usize) < NSRC {
                    self.priority[src as usize] = val as u32;
                }
            }
            _ => {}
        }
    }
}

impl Default for Plic {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raise_enable_claim_complete() {
        let mut p = Plic::new();
        p.write(4 * 5, 3); // priority[5] = 3
        p.raise(5);
        assert_eq!(p.irq_lines(), (false, false), "not enabled yet");
        p.write(ENABLE_BASE, 1 << 5); // M context enable
        assert_eq!(p.irq_lines(), (true, false));
        let src = p.claim(0);
        assert_eq!(src, 5);
        assert_eq!(p.irq_lines(), (false, false), "claimed clears pending");
        p.write(CONTEXT_BASE + 4, 5); // complete
        assert_eq!(p.claimed[0], 0);
    }

    #[test]
    fn threshold_masks() {
        let mut p = Plic::new();
        p.write(4 * 3, 1);
        p.raise(3);
        p.write(ENABLE_BASE + ENABLE_STRIDE, 1 << 3); // S context
        assert_eq!(p.irq_lines(), (false, true));
        p.write(CONTEXT_BASE + CONTEXT_STRIDE, 5); // S threshold = 5 > prio 1
        assert_eq!(p.irq_lines(), (false, false));
    }

    #[test]
    fn source_zero_never_raises() {
        let mut p = Plic::new();
        p.raise(0);
        assert_eq!(p.pending, 0);
    }
}
