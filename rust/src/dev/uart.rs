//! Minimal 8250-style UART: transmit-only console with an optional capture
//! buffer (tests and the sweep harness read the captured output instead of
//! the host terminal).
//!
//! Two capture modes:
//! - **retained** (default): every byte is kept in `output` — full-console
//!   consumers (`output_string`) see everything;
//! - **streamed** ([`Uart::stream_digest`]): bytes beyond a bounded tail
//!   are folded into a rolling SHA-256, so a fleet of hundreds of guests
//!   holds O(tail) console bytes per guest instead of O(console). Either
//!   mode produces the same [`ConsoleDigest`] for the same byte stream.

use crate::util::{ConsoleDigest, Sha256, CONSOLE_TAIL};

const THR: u64 = 0; // transmit holding register (write) / RBR (read)
const LSR: u64 = 5; // line status register

/// LSR: transmitter empty + THR empty — always ready.
const LSR_READY: u64 = 0x60;

/// Fold threshold for streamed mode: when the retained buffer grows past
/// this, everything but the last [`CONSOLE_TAIL`] bytes is hashed and
/// dropped (amortized O(1) per byte).
const FOLD_AT: usize = 4 * 1024;

#[derive(Clone, Debug)]
struct Stream {
    hasher: Sha256,
    /// Bytes already folded into `hasher` (and no longer in `output`).
    folded: u64,
}

#[derive(Clone, Debug)]
pub struct Uart {
    /// Captured output: the full stream (retained mode) or its bounded
    /// tail (streamed mode).
    pub output: Vec<u8>,
    /// Mirror writes to the host stdout.
    pub echo: bool,
    stream: Option<Stream>,
}

impl Uart {
    pub fn new() -> Uart {
        Uart { output: Vec::new(), echo: false, stream: None }
    }

    /// Switch to streamed capture: keep a bounded tail, fold the rest
    /// into a rolling SHA-256. Bytes already captured stay unfolded until
    /// the buffer next grows past the threshold, so enabling this at any
    /// point preserves the digest of the whole stream.
    pub fn stream_digest(&mut self) {
        if self.stream.is_none() {
            self.stream = Some(Stream { hasher: Sha256::new(), folded: 0 });
        }
    }

    /// True when output beyond the tail is being folded into a digest.
    pub fn is_streaming(&self) -> bool {
        self.stream.is_some()
    }

    /// Total bytes ever written (folded + retained), without touching
    /// the hash state — cheap enough to poll at every slice boundary.
    pub fn stream_len(&self) -> u64 {
        self.stream.as_ref().map_or(0, |st| st.folded) + self.output.len() as u64
    }

    pub fn read(&self, off: u64) -> u64 {
        match off {
            LSR => LSR_READY,
            _ => 0,
        }
    }

    pub fn write(&mut self, off: u64, byte: u8) {
        if off == THR {
            self.output.push(byte);
            if self.echo {
                use std::io::Write;
                let _ = std::io::stdout().write_all(&[byte]);
                if byte == b'\n' {
                    let _ = std::io::stdout().flush();
                }
            }
            if let Some(st) = &mut self.stream {
                if self.output.len() > FOLD_AT {
                    let cut = self.output.len() - CONSOLE_TAIL;
                    st.hasher.update(&self.output[..cut]);
                    st.folded += cut as u64;
                    self.output.drain(..cut);
                }
            }
        }
    }

    /// Captured output as a lossy string — the full console in retained
    /// mode, the bounded tail in streamed mode.
    pub fn output_string(&self) -> String {
        String::from_utf8_lossy(&self.output).into_owned()
    }

    /// Digest of the complete byte stream seen so far (identical across
    /// capture modes).
    pub fn digest(&self) -> ConsoleDigest {
        let (mut hasher, folded) = match &self.stream {
            Some(st) => (st.hasher.clone(), st.folded),
            None => (Sha256::new(), 0),
        };
        hasher.update(&self.output);
        let tail_at = self.output.len().saturating_sub(CONSOLE_TAIL);
        ConsoleDigest {
            sha256: hasher.finalize(),
            len: folded + self.output.len() as u64,
            tail: String::from_utf8_lossy(&self.output[tail_at..]).into_owned(),
        }
    }
}

impl Default for Uart {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn captures_output() {
        let mut u = Uart::new();
        for b in b"hi\n" {
            u.write(THR, *b);
        }
        assert_eq!(u.output_string(), "hi\n");
    }

    #[test]
    fn lsr_always_ready() {
        let u = Uart::new();
        assert_eq!(u.read(LSR) & 0x20, 0x20);
    }

    #[test]
    fn streamed_digest_matches_retained() {
        // Long enough to force several folds.
        let msg: Vec<u8> = (0..20_000u32).map(|i| b'A' + (i % 23) as u8).collect();
        let mut full = Uart::new();
        let mut streamed = Uart::new();
        streamed.stream_digest();
        for &b in &msg {
            full.write(THR, b);
            streamed.write(THR, b);
        }
        assert!(streamed.output.len() <= FOLD_AT, "tail stays bounded");
        assert_eq!(full.digest(), streamed.digest());
        assert_eq!(full.digest(), ConsoleDigest::of_bytes(&msg));
        assert_eq!(streamed.digest().len, msg.len() as u64);
        assert_eq!(streamed.digest().tail.as_bytes(), &msg[msg.len() - CONSOLE_TAIL..]);
    }

    #[test]
    fn short_streams_never_fold() {
        let mut u = Uart::new();
        u.stream_digest();
        for b in b"mini-os: up\n" {
            u.write(THR, *b);
        }
        assert_eq!(u.output_string(), "mini-os: up\n");
        assert_eq!(u.digest(), ConsoleDigest::of_bytes(b"mini-os: up\n"));
    }

    #[test]
    fn enabling_mid_stream_keeps_whole_stream_digest() {
        let msg: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let mut u = Uart::new();
        for &b in &msg[..5_000] {
            u.write(THR, b);
        }
        u.stream_digest();
        for &b in &msg[5_000..] {
            u.write(THR, b);
        }
        assert_eq!(u.digest(), ConsoleDigest::of_bytes(&msg));
    }
}
