//! Minimal 8250-style UART: transmit-only console with an optional capture
//! buffer (tests and the sweep harness read the captured output instead of
//! the host terminal).

const THR: u64 = 0; // transmit holding register (write) / RBR (read)
const LSR: u64 = 5; // line status register

/// LSR: transmitter empty + THR empty — always ready.
const LSR_READY: u64 = 0x60;

#[derive(Clone, Debug)]
pub struct Uart {
    /// Captured output (always recorded).
    pub output: Vec<u8>,
    /// Mirror writes to the host stdout.
    pub echo: bool,
}

impl Uart {
    pub fn new() -> Uart {
        Uart { output: Vec::new(), echo: false }
    }

    pub fn read(&self, off: u64) -> u64 {
        match off {
            LSR => LSR_READY,
            _ => 0,
        }
    }

    pub fn write(&mut self, off: u64, byte: u8) {
        if off == THR {
            self.output.push(byte);
            if self.echo {
                use std::io::Write;
                let _ = std::io::stdout().write_all(&[byte]);
                if byte == b'\n' {
                    let _ = std::io::stdout().flush();
                }
            }
        }
    }

    /// Captured output as a lossy string.
    pub fn output_string(&self) -> String {
        String::from_utf8_lossy(&self.output).into_owned()
    }
}

impl Default for Uart {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn captures_output() {
        let mut u = Uart::new();
        for b in b"hi\n" {
            u.write(THR, *b);
        }
        assert_eq!(u.output_string(), "hi\n");
    }

    #[test]
    fn lsr_always_ready() {
        let u = Uart::new();
        assert_eq!(u.read(LSR) & 0x20, 0x20);
    }
}
