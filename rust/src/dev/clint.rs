//! CLINT: core-local interruptor. Drives mip.MSIP (software) and mip.MTIP
//! (timer compare) — the machine-level interrupt sources of paper Fig. 2.

/// Register offsets (single hart).
const MSIP: u64 = 0x0;
const MTIMECMP: u64 = 0x4000;
const MTIME: u64 = 0xbff8;

#[derive(Clone, Debug)]
pub struct Clint {
    pub mtime: u64,
    pub mtimecmp: u64,
    pub msip: bool,
}

impl Clint {
    pub fn new() -> Clint {
        Clint { mtime: 0, mtimecmp: u64::MAX, msip: false }
    }

    /// Advance the timebase. Returns true if interrupt lines may have
    /// changed (caller refreshes mip).
    pub fn tick(&mut self, delta: u64) -> bool {
        self.mtime = self.mtime.wrapping_add(delta);
        true
    }

    /// Current mip.MTIP level.
    pub fn mtip(&self) -> bool {
        self.mtime >= self.mtimecmp
    }

    /// Current mip.MSIP level.
    pub fn msip(&self) -> bool {
        self.msip
    }

    pub fn read(&self, off: u64, size: u64) -> u64 {
        let v = match off & !7 {
            MSIP => self.msip as u64,
            MTIMECMP => self.mtimecmp,
            MTIME => self.mtime,
            _ => 0,
        };
        // Sub-word access (e.g. lw of mtime low half).
        if size == 4 && off & 4 != 0 {
            v >> 32
        } else if size == 4 {
            v & 0xffff_ffff
        } else {
            v
        }
    }

    pub fn write(&mut self, off: u64, size: u64, val: u64) {
        match off & !7 {
            MSIP => self.msip = val & 1 != 0,
            MTIMECMP => {
                if size == 8 {
                    self.mtimecmp = val;
                } else if off & 4 != 0 {
                    self.mtimecmp = (self.mtimecmp & 0xffff_ffff) | (val << 32);
                } else {
                    self.mtimecmp = (self.mtimecmp & !0xffff_ffff) | (val & 0xffff_ffff);
                }
            }
            MTIME => self.mtime = val,
            _ => {}
        }
    }
}

impl Default for Clint {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_compare_fires() {
        let mut c = Clint::new();
        c.write(MTIMECMP, 8, 100);
        assert!(!c.mtip());
        c.tick(99);
        assert!(!c.mtip());
        c.tick(1);
        assert!(c.mtip());
        // Re-arming clears it.
        c.write(MTIMECMP, 8, 200);
        assert!(!c.mtip());
    }

    #[test]
    fn msip_set_clear() {
        let mut c = Clint::new();
        c.write(MSIP, 4, 1);
        assert!(c.msip());
        c.write(MSIP, 4, 0);
        assert!(!c.msip());
    }

    #[test]
    fn split_word_mtimecmp() {
        let mut c = Clint::new();
        c.write(MTIMECMP, 4, 0xdead_beef);
        c.write(MTIMECMP + 4, 4, 0x1234);
        assert_eq!(c.mtimecmp, 0x1234_dead_beef);
        assert_eq!(c.read(MTIMECMP, 4), 0xdead_beef);
        assert_eq!(c.read(MTIMECMP + 4, 4), 0x1234);
    }

    #[test]
    fn mtime_readable() {
        let mut c = Clint::new();
        c.tick(42);
        assert_eq!(c.read(MTIME, 8), 42);
    }
}
