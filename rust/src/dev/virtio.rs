//! Paravirtual virtio-style MMIO devices (DESIGN.md §22).
//!
//! Two devices hang off the [`Bus`](crate::mem::Bus) registration table:
//!
//! * a **queue/net device** at `0x1000_1000` backed by a deterministic
//!   host-side open-loop traffic generator (seeded arrivals, fixed
//!   request content), serving the `kvstore`/`echo` guest benchmarks;
//! * a **block device** at `0x1000_2000` backed by a procedurally
//!   generated read-only host image (no backing storage to checkpoint).
//!
//! MMIO reads/writes only latch register state and doorbell flags; all
//! DMA (descriptor-ring traffic through guest RAM), request generation,
//! completion validation, latency stamping and PLIC line changes happen
//! in [`service`](VirtioQueue::service), called from
//! `Machine::device_update` on the node timebase — the single place
//! device state may reach `mip` (DESIGN.md §19).
//!
//! The ring layout is the legacy virtio split-ring subset: a descriptor
//! table of 16-byte `{addr u64, len u32, flags u16, next u16}` entries,
//! an avail ring `{flags u16, idx u16, ring[N] u16}` and a used ring
//! `{flags u16, idx u16, {id u32, len u32}[N]}`, all in guest RAM at
//! guest-programmed addresses. The device relocates every guest address
//! by the firmware-programmed `DMA_OFF` register (0 native,
//! `GUEST_OFF` under the hypervisor), keeping the kernel image
//! bit-identical in both worlds.

use std::collections::VecDeque;

use crate::dev::{MmioDevice, Plic};
use crate::mem::{CodeTracker, RamStore, RAM_BASE};

/// "virt" in little-endian byte order, as real virtio-mmio exposes.
pub const VIRTIO_MAGIC: u32 = 0x7472_6976;
pub const VIRTIO_QUEUE_BASE: u64 = 0x1000_1000;
pub const VIRTIO_BLK_BASE: u64 = 0x1000_2000;
pub const VIRTIO_SIZE: u64 = 0x1000;
/// PLIC source lines for the completion interrupts.
pub const VIRTIO_QUEUE_IRQ: u32 = 8;
pub const VIRTIO_BLK_IRQ: u32 = 9;

/// Nominal simulated clock for open-loop arrival conversion: `--rate`
/// is requests/second; one second is this many node ticks.
pub const TICKS_PER_SEC: u64 = 1_000_000_000;
/// Default open-loop arrival rate (requests/second).
pub const DEFAULT_RATE: u64 = 1_000_000;

/// Ring depth both devices expose via `QUEUE_NUM_MAX`.
pub const VIRTQ_SIZE: u32 = 8;

/// Block device geometry: 128 × 512-byte sectors, read-only.
pub const BLK_SECTORS: u64 = 128;
pub const BLK_SECTOR_SIZE: u64 = 512;

// Common register map (offsets within each device's 4 KiB aperture).
pub const REG_MAGIC: u64 = 0x00;
pub const REG_DEVICE_ID: u64 = 0x04;
pub const REG_STATUS: u64 = 0x08;
pub const REG_FEATURES: u64 = 0x0c;
pub const REG_QUEUE_NUM_MAX: u64 = 0x10;
pub const REG_QUEUE_NUM: u64 = 0x14;
pub const REG_DESC: u64 = 0x18;
pub const REG_AVAIL: u64 = 0x20;
pub const REG_USED: u64 = 0x28;
pub const REG_NOTIFY: u64 = 0x30;
pub const REG_INT_STATUS: u64 = 0x34;
pub const REG_INT_ACK: u64 = 0x38;
pub const REG_DMA_OFF: u64 = 0x40;
// Queue/net device extras.
pub const REG_RATE: u64 = 0x50;
pub const REG_SEED: u64 = 0x58;
pub const REG_REQ_TOTAL: u64 = 0x60;
pub const REG_MODE: u64 = 0x64;
pub const REG_COMPLETED: u64 = 0x68;
pub const REG_ERRORS: u64 = 0x6c;
pub const REG_RESP: u64 = 0x70;
pub const REG_COMPLETE: u64 = 0x78;
// Block device extra.
pub const REG_CAPACITY: u64 = 0x50;

pub const STATUS_DRIVER_OK: u32 = 0x4;
pub const DESC_F_NEXT: u16 = 1;
pub const DESC_F_WRITE: u16 = 2;

/// Workload modes of the queue device.
pub const MODE_ECHO: u32 = 0;
pub const MODE_KV: u32 = 1;
/// Key space of the kv workload (and the device's shadow table).
pub const KV_SLOTS: usize = 256;

/// Device-side events latched during MMIO handling / service, drained
/// by `Machine::device_update` into the telemetry layer. Kept as a
/// plain enum so `mem` does not depend on `telemetry`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DevEvent {
    /// A guest access to a virtio aperture (UART/CLINT/PLIC accesses
    /// are deliberately not ring-logged — they would flood the rings).
    MmioAccess { addr: u64, write: bool },
    /// A completion line raised into the PLIC (0→1 transitions only).
    IrqInject { irq: u32 },
    /// A request retired by the guest: latency in node ticks.
    VirtqComplete { id: u32, latency: u64 },
}

#[inline]
fn xorshift64(mut x: u64) -> u64 {
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    x
}

/// Procedural content of the read-only block image (never stored).
#[inline]
pub fn blk_image_byte(i: u64) -> u8 {
    ((i.wrapping_add(1).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (i >> 7)) >> 24) as u8
}

#[inline]
fn dma_ok(ram: &RamStore, addr: u64, size: u64) -> bool {
    // checked_add: a guest can program addresses near u64::MAX; the sum
    // must reject on wraparound, not panic (debug) or pass (release).
    match addr.checked_add(size) {
        Some(end) => addr >= RAM_BASE && end <= RAM_BASE + ram.len() as u64,
        None => false,
    }
}

#[inline]
fn dma_read(ram: &RamStore, addr: u64, size: u64) -> u64 {
    ram.read((addr - RAM_BASE) as usize, size)
}

#[inline]
fn dma_write(ram: &mut RamStore, code: &mut CodeTracker, addr: u64, size: u64, val: u64) {
    let off = (addr - RAM_BASE) as usize;
    if code.any() {
        code.note_write(off, size as usize);
    }
    ram.write(off, size, val);
}

/// Merge a size-4/size-8 register write into a 64-bit register.
#[inline]
fn merge64(cur: u64, hi_half: bool, size: u64, val: u64) -> u64 {
    if size == 8 {
        val
    } else if hi_half {
        (cur & 0xffff_ffff) | (val << 32)
    } else {
        (cur & !0xffff_ffff) | (val & 0xffff_ffff)
    }
}

#[inline]
fn read64(cur: u64, hi_half: bool, size: u64) -> u64 {
    if size == 8 {
        cur
    } else if hi_half {
        cur >> 32
    } else {
        cur & 0xffff_ffff
    }
}

/// One legacy-layout virtqueue: guest-programmed ring addresses plus
/// the device's consumption cursors.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Virtq {
    pub num: u32,
    pub desc: u64,
    pub avail: u64,
    pub used: u64,
    /// Next avail-ring slot the device will consume.
    pub avail_seen: u16,
    /// Device-side shadow of `used.idx` (the value last written back).
    pub used_idx: u16,
}

impl Virtq {
    fn reset(&mut self) {
        *self = Virtq::default();
    }

    fn rings_ok(&self, ram: &RamStore, dma_off: u64) -> bool {
        let n = self.num as u64;
        n > 0
            && n <= VIRTQ_SIZE as u64
            && dma_ok(ram, self.desc.wrapping_add(dma_off), 16 * n)
            && dma_ok(ram, self.avail.wrapping_add(dma_off), 4 + 2 * n)
            && dma_ok(ram, self.used.wrapping_add(dma_off), 4 + 8 * n)
    }

    /// Pop the next guest-posted descriptor head, if any.
    ///
    /// Ring addresses use the same wrapping arithmetic `rings_ok`
    /// validated with, so a near-u64::MAX guest address that wraps into
    /// RAM is either consistently accepted or consistently rejected —
    /// never a debug-build overflow panic.
    fn pop_avail(&mut self, ram: &RamStore, dma_off: u64) -> Option<u16> {
        let avail = self.avail.wrapping_add(dma_off);
        let idx = dma_read(ram, avail.wrapping_add(2), 2) as u16;
        if idx == self.avail_seen {
            return None;
        }
        let slot = (self.avail_seen % self.num as u16) as u64;
        let head = dma_read(ram, avail.wrapping_add(4 + 2 * slot), 2) as u16;
        self.avail_seen = self.avail_seen.wrapping_add(1);
        Some(head)
    }

    /// Read descriptor `i`: (addr, len, flags, next). `i % num` keeps any
    /// guest-supplied index (including hostile `next` pointers) inside
    /// the validated table.
    fn desc(&self, ram: &RamStore, dma_off: u64, i: u16) -> (u64, u32, u16, u16) {
        let base = self
            .desc
            .wrapping_add(dma_off)
            .wrapping_add(16 * (i % self.num as u16) as u64);
        (
            dma_read(ram, base, 8),
            dma_read(ram, base.wrapping_add(8), 4) as u32,
            dma_read(ram, base.wrapping_add(12), 2) as u16,
            dma_read(ram, base.wrapping_add(14), 2) as u16,
        )
    }

    /// Publish a used-ring element and bump the guest-visible index.
    fn push_used(
        &mut self,
        ram: &mut RamStore,
        code: &mut CodeTracker,
        dma_off: u64,
        id: u32,
        len: u32,
    ) {
        let used = self.used.wrapping_add(dma_off);
        let slot = (self.used_idx % self.num as u16) as u64;
        let elem = used.wrapping_add(4 + 8 * slot);
        dma_write(ram, code, elem, 4, id as u64);
        dma_write(ram, code, elem.wrapping_add(4), 4, len as u64);
        self.used_idx = self.used_idx.wrapping_add(1);
        dma_write(ram, code, used.wrapping_add(2), 2, self.used_idx as u64);
    }
}

/// A generated request while it waits for an RX buffer (backlog) or a
/// guest response (in flight).
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct Req {
    pub(crate) id: u32,
    pub(crate) op: u64,
    pub(crate) key: u64,
    pub(crate) val: u64,
    pub(crate) expected: u64,
    /// Scheduled arrival in node ticks (latency anchor).
    pub(crate) arrival: u64,
}

/// The queue/net device: an open-loop request source with device-side
/// response validation and per-request latency capture.
#[derive(Clone)]
pub struct VirtioQueue {
    pub status: u32,
    pub int_status: u32,
    /// Host-physical relocation added to every guest DMA address
    /// (firmware-programmed: 0 native, `GUEST_OFF` under the
    /// hypervisor). Survives device reset.
    pub dma_off: u64,
    pub q: Virtq,
    /// Open-loop arrival rate, requests/second (host-configured;
    /// survives device reset — `--rate` owns it, not the guest).
    pub rate: u64,
    pub seed: u64,
    pub mode: u32,
    pub req_total: u32,
    pub resp: u64,
    pub completed: u32,
    pub errors: u32,
    /// Per-request latency (arrival node tick → completion-service
    /// node tick), in completion order.
    pub latencies: Vec<u64>,
    // ---- generator / protocol state (checkpointed) ----
    pub(crate) rng: u64,
    pub(crate) started: bool,
    pub(crate) start_pending: bool,
    pub(crate) next_arrival: u64,
    pub(crate) generated: u32,
    pub(crate) backlog: VecDeque<Req>,
    pub(crate) inflight: Vec<Req>,
    pub(crate) shadow: Vec<u64>,
    pub(crate) irq_raised: bool,
    pub(crate) ack: bool,
    pub(crate) completes: Vec<(u32, u64)>,
    // ---- injected faults (chaos layer; host-owned, survive guest
    // reset, never checkpointed — a restore always clears them) ----
    /// While set, `service` is completely frozen: no DMA, no used-ring
    /// writes, no interrupt-line changes. A polling guest wedges.
    pub fault_wedge: bool,
    /// Force the next `n` RX deliveries to complete with a zero-length
    /// (error) used element, delivering no request content.
    pub fault_error_n: u32,
}

impl Default for VirtioQueue {
    fn default() -> Self {
        VirtioQueue::new()
    }
}

impl VirtioQueue {
    pub fn new() -> VirtioQueue {
        VirtioQueue {
            status: 0,
            int_status: 0,
            dma_off: 0,
            q: Virtq::default(),
            rate: DEFAULT_RATE,
            seed: 0,
            mode: MODE_ECHO,
            req_total: 0,
            resp: 0,
            completed: 0,
            errors: 0,
            latencies: Vec::new(),
            rng: 0,
            started: false,
            start_pending: false,
            next_arrival: 0,
            generated: 0,
            backlog: VecDeque::new(),
            inflight: Vec::new(),
            shadow: vec![0; KV_SLOTS],
            irq_raised: false,
            ack: false,
            completes: Vec::new(),
            fault_wedge: false,
            fault_error_n: 0,
        }
    }

    /// Guest-visible reset (STATUS ← 0). `dma_off` and `rate` are
    /// host/firmware-owned and survive, as do injected faults — a guest
    /// cannot clear a fault by resetting its device.
    fn reset(&mut self) {
        let (dma_off, rate) = (self.dma_off, self.rate);
        let (wedge, err_n) = (self.fault_wedge, self.fault_error_n);
        *self = VirtioQueue::new();
        self.dma_off = dma_off;
        self.rate = rate;
        self.fault_wedge = wedge;
        self.fault_error_n = err_n;
    }

    /// Inter-arrival gap in node ticks, drawn from the arrival stream:
    /// uniform in [interval/2, 3·interval/2) around the mean interval.
    fn draw_gap(&mut self) -> u64 {
        let interval = (TICKS_PER_SEC / self.rate.max(1)).max(1);
        self.rng = xorshift64(self.rng);
        interval / 2 + self.rng % interval
    }

    /// Generate request content (one content draw per request) and the
    /// mode-dependent expected response, updating the kv shadow table.
    fn draw_request(&mut self, arrival: u64) -> Req {
        let id = self.generated;
        self.rng = xorshift64(self.rng);
        let r = self.rng;
        let op = r & 1;
        let key = (r >> 1) & (KV_SLOTS as u64 - 1);
        let val = r >> 9;
        let expected = if self.mode == MODE_KV {
            let old = self.shadow[key as usize];
            if op == 1 {
                self.shadow[key as usize] = val;
            }
            old
        } else {
            key ^ val ^ id as u64
        };
        self.generated += 1;
        Req { id, op, key, val, expected, arrival }
    }

    /// Deferred device work, on the node timebase. The only place this
    /// device touches guest RAM or the PLIC.
    pub(crate) fn service(
        &mut self,
        now: u64,
        ram: &mut RamStore,
        code: &mut CodeTracker,
        plic: &mut Plic,
        events: &mut Vec<DevEvent>,
    ) {
        if self.fault_wedge {
            // Injected device hang: frozen until recovery replaces the
            // device state. The IRQ line stays wherever it was.
            return;
        }
        if self.ack {
            self.ack = false;
            self.int_status = 0;
        }
        if self.start_pending {
            self.start_pending = false;
            self.started = true;
            self.rng = self.seed;
            self.next_arrival = now + self.draw_gap();
        }
        if self.started && self.q.rings_ok(ram, self.dma_off) {
            // Open-loop arrivals: catch up the seeded schedule to `now`;
            // backlogged arrivals keep their scheduled arrival stamps so
            // queueing delay counts toward request latency.
            while self.generated < self.req_total && now >= self.next_arrival {
                let arrival = self.next_arrival;
                let req = self.draw_request(arrival);
                self.backlog.push_back(req);
                let gap = self.draw_gap();
                self.next_arrival += gap;
            }
            // Deliver backlog into guest-posted RX buffers.
            while !self.backlog.is_empty() {
                let Some(head) = self.q.pop_avail(ram, self.dma_off) else { break };
                let (addr, len, _flags, _next) = self.q.desc(ram, self.dma_off, head);
                let buf = addr.wrapping_add(self.dma_off);
                if self.fault_error_n > 0 {
                    // Injected device error: consume the posted buffer
                    // and complete it zero-length, delivering nothing.
                    // The request stays backlogged for a later retry.
                    self.fault_error_n -= 1;
                    self.errors += 1;
                    self.q.push_used(ram, code, self.dma_off, head as u32, 0);
                    self.int_status |= 1;
                    continue;
                }
                if len < 32 || !dma_ok(ram, buf, 32) {
                    // Malformed RX buffer: complete it zero-length
                    // (error) instead of leaking it — the guest gets the
                    // buffer back and the device stays live.
                    self.errors += 1;
                    self.q.push_used(ram, code, self.dma_off, head as u32, 0);
                    self.int_status |= 1;
                    continue;
                }
                let req = self.backlog.pop_front().unwrap();
                dma_write(ram, code, buf, 8, req.id as u64);
                dma_write(ram, code, buf + 8, 8, req.op);
                dma_write(ram, code, buf + 16, 8, req.key);
                dma_write(ram, code, buf + 24, 8, req.val);
                self.q.push_used(ram, code, self.dma_off, head as u32, 32);
                self.inflight.push(req);
                self.int_status |= 1;
            }
            // Retire guest completions (COMPLETE doorbells since the
            // last service); completion tick = this service tick.
            for (id, resp) in std::mem::take(&mut self.completes) {
                match self.inflight.iter().position(|r| r.id == id) {
                    Some(i) => {
                        let req = self.inflight.swap_remove(i);
                        if resp != req.expected {
                            self.errors += 1;
                        }
                        self.completed += 1;
                        self.latencies.push(now - req.arrival);
                        events.push(DevEvent::VirtqComplete {
                            id,
                            latency: now - req.arrival,
                        });
                    }
                    None => self.errors += 1,
                }
            }
        } else {
            self.completes.clear();
        }
        // Level-triggered completion line into the PLIC.
        if self.int_status != 0 {
            if !self.irq_raised {
                self.irq_raised = true;
                plic.raise(VIRTIO_QUEUE_IRQ);
                events.push(DevEvent::IrqInject { irq: VIRTIO_QUEUE_IRQ });
            }
        } else if self.irq_raised {
            self.irq_raised = false;
            plic.pending &= !(1 << VIRTIO_QUEUE_IRQ);
        }
    }
}

impl MmioDevice for VirtioQueue {
    fn read(&mut self, off: u64, size: u64) -> u64 {
        match off {
            REG_MAGIC => VIRTIO_MAGIC as u64,
            REG_DEVICE_ID => 1,
            REG_STATUS => self.status as u64,
            REG_FEATURES => 0,
            REG_QUEUE_NUM_MAX => VIRTQ_SIZE as u64,
            REG_QUEUE_NUM => self.q.num as u64,
            REG_DESC | 0x1c => read64(self.q.desc, off == 0x1c, size),
            REG_AVAIL | 0x24 => read64(self.q.avail, off == 0x24, size),
            REG_USED | 0x2c => read64(self.q.used, off == 0x2c, size),
            REG_INT_STATUS => self.int_status as u64,
            REG_DMA_OFF | 0x44 => read64(self.dma_off, off == 0x44, size),
            REG_RATE | 0x54 => read64(self.rate, off == 0x54, size),
            REG_SEED | 0x5c => read64(self.seed, off == 0x5c, size),
            REG_REQ_TOTAL => self.req_total as u64,
            REG_MODE => self.mode as u64,
            REG_COMPLETED => self.completed as u64,
            REG_ERRORS => self.errors as u64,
            REG_RESP | 0x74 => read64(self.resp, off == 0x74, size),
            _ => 0,
        }
    }

    fn write(&mut self, off: u64, size: u64, val: u64) {
        match off {
            REG_STATUS => {
                let new = val as u32;
                if new == 0 {
                    self.reset();
                    return;
                }
                if new & STATUS_DRIVER_OK != 0 && self.status & STATUS_DRIVER_OK == 0 {
                    self.start_pending = true;
                }
                self.status = new;
            }
            REG_QUEUE_NUM => self.q.num = (val as u32).min(VIRTQ_SIZE),
            REG_DESC | 0x1c => self.q.desc = merge64(self.q.desc, off == 0x1c, size, val),
            REG_AVAIL | 0x24 => self.q.avail = merge64(self.q.avail, off == 0x24, size, val),
            REG_USED | 0x2c => self.q.used = merge64(self.q.used, off == 0x2c, size, val),
            REG_NOTIFY => {} // avail is rescanned every service tick
            REG_INT_ACK => self.ack = true,
            REG_DMA_OFF | 0x44 => self.dma_off = merge64(self.dma_off, off == 0x44, size, val),
            REG_RATE | 0x54 => self.rate = merge64(self.rate, off == 0x54, size, val).max(1),
            REG_SEED | 0x5c => self.seed = merge64(self.seed, off == 0x5c, size, val),
            REG_REQ_TOTAL => self.req_total = val as u32,
            REG_MODE => self.mode = val as u32,
            REG_RESP | 0x74 => self.resp = merge64(self.resp, off == 0x74, size, val),
            REG_COMPLETE => self.completes.push((val as u32, self.resp)),
            _ => {}
        }
    }
}

/// The block device: a read-only, procedurally generated 64 KiB image
/// served through a 3-descriptor chain (header / data / status).
#[derive(Clone)]
pub struct VirtioBlk {
    pub status: u32,
    pub int_status: u32,
    pub dma_off: u64,
    pub q: Virtq,
    pub ops: u32,
    pub errors: u32,
    pub(crate) notify: bool,
    pub(crate) ack: bool,
    pub(crate) irq_raised: bool,
    // ---- injected faults (chaos layer; host-owned, survive guest
    // reset, never checkpointed — a restore always clears them) ----
    /// While set, `service` is completely frozen (no DMA, no used-ring
    /// writes, no interrupt-line changes). A polling guest wedges.
    pub fault_wedge: bool,
    /// Force the next `n` requests to complete with I/O-error status.
    pub fault_error_n: u32,
}

impl Default for VirtioBlk {
    fn default() -> Self {
        VirtioBlk::new()
    }
}

impl VirtioBlk {
    pub fn new() -> VirtioBlk {
        VirtioBlk {
            status: 0,
            int_status: 0,
            dma_off: 0,
            q: Virtq::default(),
            ops: 0,
            errors: 0,
            notify: false,
            ack: false,
            irq_raised: false,
            fault_wedge: false,
            fault_error_n: 0,
        }
    }

    fn reset(&mut self) {
        let dma_off = self.dma_off;
        let (wedge, err_n) = (self.fault_wedge, self.fault_error_n);
        *self = VirtioBlk::new();
        self.dma_off = dma_off;
        self.fault_wedge = wedge;
        self.fault_error_n = err_n;
    }

    /// Process one queued request chain: header desc {type u64, sector
    /// u64}, data desc (device-written for reads), status desc (1 byte;
    /// 0 = ok, 2 = I/O error). Only reads are supported.
    ///
    /// Every popped head is *completed* — malformed chains (zero-length
    /// or out-of-bounds descriptors, self-looping `next` pointers, a
    /// truncated chain) get an error status byte when the status
    /// descriptor is reachable and a used-ring element either way, so a
    /// buggy or hostile guest driver sees a clean I/O error instead of
    /// wedging on a never-returned buffer (and never panics the host).
    fn process(&mut self, ram: &mut RamStore, code: &mut CodeTracker, head: u16) {
        let n = self.q.num as u16;
        let forced_err = self.fault_error_n > 0;
        if forced_err {
            self.fault_error_n -= 1;
        }
        let (haddr, hlen, hflags, hnext) = self.q.desc(ram, self.dma_off, head);
        let hbuf = haddr.wrapping_add(self.dma_off);
        let header_ok = hlen >= 16
            && hflags & DESC_F_NEXT != 0
            && dma_ok(ram, hbuf, 16)
            && hnext % n != head % n;
        let mut status_buf = None;
        let mut ok = false;
        if header_ok {
            let optype = dma_read(ram, hbuf, 8);
            let sector = dma_read(ram, hbuf + 8, 8);
            let (daddr, dlen, dflags, dnext) = self.q.desc(ram, self.dma_off, hnext);
            let dbuf = daddr.wrapping_add(self.dma_off);
            let (saddr, slen, _sflags, _snext) = self.q.desc(ram, self.dma_off, dnext);
            let sbuf = saddr.wrapping_add(self.dma_off);
            // The status byte is written only through a well-formed,
            // loop-free chain — an aliased status descriptor would
            // scribble on the header or data buffer.
            let chain_ok = dflags & DESC_F_NEXT != 0
                && dnext % n != head % n
                && dnext % n != hnext % n;
            if chain_ok && slen >= 1 && dma_ok(ram, sbuf, 1) {
                status_buf = Some(sbuf);
            }
            ok = status_buf.is_some()
                && !forced_err
                && optype == 0
                && sector < BLK_SECTORS
                && dlen as u64 >= BLK_SECTOR_SIZE
                && dflags & DESC_F_WRITE != 0
                && dma_ok(ram, dbuf, BLK_SECTOR_SIZE);
            if ok {
                for w in 0..BLK_SECTOR_SIZE / 8 {
                    let mut word = 0u64;
                    for b in 0..8 {
                        let i = sector * BLK_SECTOR_SIZE + w * 8 + b;
                        word |= (blk_image_byte(i) as u64) << (8 * b);
                    }
                    dma_write(ram, code, dbuf + w * 8, 8, word);
                }
            }
        }
        if !ok {
            self.errors += 1;
        }
        if let Some(sbuf) = status_buf {
            dma_write(ram, code, sbuf, 1, if ok { 0 } else { 2 });
        }
        let len = if ok { BLK_SECTOR_SIZE as u32 + 1 } else { 1 };
        self.q.push_used(ram, code, self.dma_off, head as u32, len);
        self.ops += 1;
        self.int_status |= 1;
    }

    pub(crate) fn service(
        &mut self,
        ram: &mut RamStore,
        code: &mut CodeTracker,
        plic: &mut Plic,
        events: &mut Vec<DevEvent>,
    ) {
        if self.fault_wedge {
            // Injected device hang: frozen until recovery replaces the
            // device state. The IRQ line stays wherever it was.
            return;
        }
        if self.ack {
            self.ack = false;
            self.int_status = 0;
        }
        if self.notify {
            self.notify = false;
            if self.status & STATUS_DRIVER_OK != 0 && self.q.rings_ok(ram, self.dma_off) {
                while let Some(head) = self.q.pop_avail(ram, self.dma_off) {
                    self.process(ram, code, head);
                }
            }
        }
        if self.int_status != 0 {
            if !self.irq_raised {
                self.irq_raised = true;
                plic.raise(VIRTIO_BLK_IRQ);
                events.push(DevEvent::IrqInject { irq: VIRTIO_BLK_IRQ });
            }
        } else if self.irq_raised {
            self.irq_raised = false;
            plic.pending &= !(1 << VIRTIO_BLK_IRQ);
        }
    }
}

impl MmioDevice for VirtioBlk {
    fn read(&mut self, off: u64, size: u64) -> u64 {
        match off {
            REG_MAGIC => VIRTIO_MAGIC as u64,
            REG_DEVICE_ID => 2,
            REG_STATUS => self.status as u64,
            REG_FEATURES => 0,
            REG_QUEUE_NUM_MAX => VIRTQ_SIZE as u64,
            REG_QUEUE_NUM => self.q.num as u64,
            REG_DESC | 0x1c => read64(self.q.desc, off == 0x1c, size),
            REG_AVAIL | 0x24 => read64(self.q.avail, off == 0x24, size),
            REG_USED | 0x2c => read64(self.q.used, off == 0x2c, size),
            REG_INT_STATUS => self.int_status as u64,
            REG_DMA_OFF | 0x44 => read64(self.dma_off, off == 0x44, size),
            REG_CAPACITY => BLK_SECTORS,
            _ => 0,
        }
    }

    fn write(&mut self, off: u64, size: u64, val: u64) {
        match off {
            REG_STATUS => {
                if val as u32 == 0 {
                    self.reset();
                } else {
                    self.status = val as u32;
                }
            }
            REG_QUEUE_NUM => self.q.num = (val as u32).min(VIRTQ_SIZE),
            REG_DESC | 0x1c => self.q.desc = merge64(self.q.desc, off == 0x1c, size, val),
            REG_AVAIL | 0x24 => self.q.avail = merge64(self.q.avail, off == 0x24, size, val),
            REG_USED | 0x2c => self.q.used = merge64(self.q.used, off == 0x2c, size, val),
            REG_NOTIFY => self.notify = true,
            REG_INT_ACK => self.ack = true,
            REG_DMA_OFF | 0x44 => self.dma_off = merge64(self.dma_off, off == 0x44, size, val),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::StoreKind;

    fn parts() -> (RamStore, CodeTracker, Plic, Vec<DevEvent>) {
        let ram = RamStore::new(1 << 20, StoreKind::Cow);
        let code = CodeTracker::new(ram.num_pages());
        (ram, code, Plic::new(), Vec::new())
    }

    /// Program rings at fixed offsets and post all 8 RX buffers, as the
    /// guest driver does (desc @+0, avail @+0x80, used @+0xc0,
    /// buffers @+0x140).
    fn program(dev: &mut VirtioQueue, ram: &mut RamStore, base: u64) {
        dev.write(REG_QUEUE_NUM, 4, VIRTQ_SIZE as u64);
        dev.write(REG_DESC, 8, base);
        dev.write(REG_AVAIL, 8, base + 0x80);
        dev.write(REG_USED, 8, base + 0xc0);
        for i in 0..VIRTQ_SIZE as u64 {
            let d = base - RAM_BASE + 16 * i;
            ram.write(d as usize, 8, base + 0x140 + 32 * i); // addr
            ram.write(d as usize + 8, 4, 32); // len
            ram.write((base - RAM_BASE + 0x80 + 4 + 2 * i) as usize, 2, i);
        }
        ram.write((base - RAM_BASE + 0x80 + 2) as usize, 2, VIRTQ_SIZE as u64);
    }

    fn drive(dev: &mut VirtioQueue, seed: u64, total: u32, mode: u32) -> (Vec<u64>, u32, u32) {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        program(dev, &mut ram, RAM_BASE + 0x1000);
        dev.write(REG_SEED, 8, seed);
        dev.write(REG_REQ_TOTAL, 4, total as u64);
        dev.write(REG_MODE, 4, mode as u64);
        dev.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        let mut resps = Vec::new();
        let mut last_used = 0u16;
        let mut now = 0u64;
        while resps.len() < total as usize {
            now += 100;
            dev.service(now, &mut ram, &mut code, &mut plic, &mut ev);
            let used_idx = ram.read((0x1000 + 0xc0 + 2) as usize, 2) as u16;
            while last_used != used_idx {
                let slot = (last_used % VIRTQ_SIZE as u16) as u64;
                let head = ram.read((0x1000 + 0xc0 + 4 + 8 * slot) as usize, 4);
                let buf = 0x1000 + 0x140 + 32 * head;
                let id = ram.read(buf as usize, 8);
                let key = ram.read(buf as usize + 16, 8);
                let val = ram.read(buf as usize + 24, 8);
                // Echo-mode response; kv handled by expected-shadow test.
                let resp = key ^ val ^ id;
                resps.push(resp);
                // Repost the buffer, then complete.
                let slot2 = ((VIRTQ_SIZE as u16).wrapping_add(last_used) % VIRTQ_SIZE as u16)
                    as u64;
                ram.write((0x1000 + 0x80 + 4 + 2 * slot2) as usize, 2, head);
                let avail = ram.read((0x1000 + 0x80 + 2) as usize, 2) + 1;
                ram.write((0x1000 + 0x80 + 2) as usize, 2, avail & 0xffff);
                dev.write(REG_RESP, 8, resp);
                dev.write(REG_COMPLETE, 4, id);
                last_used = last_used.wrapping_add(1);
            }
            assert!(now < 100_000_000, "generator stalled");
        }
        now += 100;
        dev.service(now, &mut ram, &mut code, &mut plic, &mut ev);
        (dev.latencies.clone(), dev.completed, dev.errors)
    }

    #[test]
    fn identity_registers() {
        let mut q = VirtioQueue::new();
        assert_eq!(q.read(REG_MAGIC, 4), VIRTIO_MAGIC as u64);
        assert_eq!(q.read(REG_DEVICE_ID, 4), 1);
        assert_eq!(q.read(REG_QUEUE_NUM_MAX, 4), VIRTQ_SIZE as u64);
        let mut b = VirtioBlk::new();
        assert_eq!(b.read(REG_DEVICE_ID, 4), 2);
        assert_eq!(b.read(REG_CAPACITY, 4), BLK_SECTORS);
    }

    #[test]
    fn split_word_64bit_registers_merge() {
        let mut q = VirtioQueue::new();
        q.write(REG_DESC, 4, 0x8000_1000);
        q.write(0x1c, 4, 0x1);
        assert_eq!(q.q.desc, 0x1_8000_1000);
        assert_eq!(q.read(REG_DESC, 8), 0x1_8000_1000);
        assert_eq!(q.read(0x1c, 4), 0x1);
        q.write(REG_DESC, 8, 0x8000_2000);
        assert_eq!(q.q.desc, 0x8000_2000);
    }

    #[test]
    fn echo_stream_is_seed_deterministic_and_validated() {
        let mut a = VirtioQueue::new();
        let mut b = VirtioQueue::new();
        let (la, ca, ea) = drive(&mut a, 0x1234, 16, MODE_ECHO);
        let (lb, cb, eb) = drive(&mut b, 0x1234, 16, MODE_ECHO);
        assert_eq!((ca, ea), (16, 0), "device validated every echo response");
        assert_eq!((cb, eb), (16, 0));
        assert_eq!(la, lb, "same seed → identical latency stream");
        let mut c = VirtioQueue::new();
        let (lc, _, _) = drive(&mut c, 0x9999, 16, MODE_ECHO);
        assert_ne!(la, lc, "different seed → different arrivals");
    }

    #[test]
    fn kv_mode_flags_wrong_responses() {
        // Echo-style responses are wrong for kv mode: the shadow table
        // must flag (most of) them without crashing or stalling.
        let mut q = VirtioQueue::new();
        let (_, completed, errors) = drive(&mut q, 0x42, 16, MODE_KV);
        assert_eq!(completed, 16);
        assert!(errors > 0, "kv shadow accepted echo responses");
    }

    #[test]
    fn rate_changes_arrival_spacing_but_not_content() {
        let mut fast = VirtioQueue::new();
        fast.rate = 10_000_000;
        let mut slow = VirtioQueue::new();
        slow.rate = 100_000;
        let (lf, _, ef) = drive(&mut fast, 7, 16, MODE_ECHO);
        let (ls, _, es) = drive(&mut slow, 7, 16, MODE_ECHO);
        // Content validated at both rates (errors == 0) even though the
        // arrival schedules differ.
        assert_eq!((ef, es), (0, 0));
        assert!(lf.len() == 16 && ls.len() == 16);
    }

    #[test]
    fn unposted_rings_never_touch_ram() {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        let mut q = VirtioQueue::new();
        q.write(REG_SEED, 8, 1);
        q.write(REG_REQ_TOTAL, 4, 4);
        q.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        // Rings left unprogrammed (num = 0): service must not DMA.
        for t in 1..100u64 {
            q.service(t * 100, &mut ram, &mut code, &mut plic, &mut ev);
        }
        assert_eq!(ram.allocated_pages(), 0, "no DMA without valid rings");
        // Garbage ring addresses are rejected, not dereferenced.
        q.write(REG_QUEUE_NUM, 4, 8);
        q.write(REG_DESC, 8, 0x10);
        q.write(REG_AVAIL, 8, 0xffff_ffff_0000);
        q.write(REG_USED, 8, RAM_BASE);
        q.service(100_000, &mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.allocated_pages(), 0);
    }

    #[test]
    fn completion_irq_is_level_triggered_through_the_plic() {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        let mut q = VirtioQueue::new();
        program(&mut q, &mut ram, RAM_BASE + 0x1000);
        q.write(REG_SEED, 8, 3);
        q.write(REG_REQ_TOTAL, 4, 1);
        q.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        let mut now = 0;
        while q.completed + q.generated < 1 || q.backlog.front().is_some() {
            now += 100;
            q.service(now, &mut ram, &mut code, &mut plic, &mut ev);
            assert!(now < 10_000_000);
        }
        assert_eq!(plic.pending & (1 << VIRTIO_QUEUE_IRQ), 1 << VIRTIO_QUEUE_IRQ);
        assert!(ev.contains(&DevEvent::IrqInject { irq: VIRTIO_QUEUE_IRQ }));
        // INT_ACK lowers the line at the next service.
        q.write(REG_INT_ACK, 4, 1);
        now += 100;
        q.service(now, &mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(plic.pending & (1 << VIRTIO_QUEUE_IRQ), 0);
        assert_eq!(q.int_status, 0);
    }

    #[test]
    fn blk_serves_deterministic_sectors_and_rejects_writes() {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        let mut b = VirtioBlk::new();
        let base = RAM_BASE + 0x2000;
        b.write(REG_QUEUE_NUM, 4, VIRTQ_SIZE as u64);
        b.write(REG_DESC, 8, base);
        b.write(REG_AVAIL, 8, base + 0x80);
        b.write(REG_USED, 8, base + 0xc0);
        b.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        let off = (base - RAM_BASE) as usize;
        let mut submit = |ram: &mut RamStore, optype: u64, sector: u64, n: u64| {
            // header desc 0 → data desc 1 → status desc 2
            ram.write(off + 0x100, 8, optype);
            ram.write(off + 0x108, 8, sector);
            ram.write(off, 8, base + 0x100);
            ram.write(off + 8, 4, 16);
            ram.write(off + 12, 2, DESC_F_NEXT as u64);
            ram.write(off + 14, 2, 1);
            ram.write(off + 16, 8, base + 0x200);
            ram.write(off + 24, 4, 512);
            ram.write(off + 28, 2, (DESC_F_NEXT | DESC_F_WRITE) as u64);
            ram.write(off + 30, 2, 2);
            ram.write(off + 32, 8, base + 0x120);
            ram.write(off + 40, 4, 1);
            ram.write(off + 44, 2, DESC_F_WRITE as u64);
            ram.write(off + 0x80 + 4 + 2 * ((n as usize - 1) % 8), 2, 0);
            ram.write(off + 0x80 + 2, 2, n);
        };
        submit(&mut ram, 0, 5, 1);
        b.write(REG_NOTIFY, 4, 0);
        b.service(&mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.read(off + 0xc0 + 2, 2), 1, "used.idx advanced");
        assert_eq!(ram.read(off + 0x120, 1), 0, "status ok");
        for i in 0..8 {
            assert_eq!(
                ram.read(off + 0x200 + i, 1) as u8,
                blk_image_byte(5 * BLK_SECTOR_SIZE + i as u64)
            );
        }
        assert_eq!(plic.pending & (1 << VIRTIO_BLK_IRQ), 1 << VIRTIO_BLK_IRQ);
        // A write op is rejected with an I/O-error status byte.
        submit(&mut ram, 1, 5, 2);
        b.write(REG_NOTIFY, 4, 0);
        b.service(&mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.read(off + 0x120, 1), 2, "write rejected as IOERR");
        assert_eq!(b.errors, 1);
        assert_eq!(b.ops, 2);
    }

    #[test]
    fn injected_blk_faults_error_then_heal() {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        let mut b = VirtioBlk::new();
        let base = RAM_BASE + 0x2000;
        b.write(REG_QUEUE_NUM, 4, VIRTQ_SIZE as u64);
        b.write(REG_DESC, 8, base);
        b.write(REG_AVAIL, 8, base + 0x80);
        b.write(REG_USED, 8, base + 0xc0);
        b.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        let off = (base - RAM_BASE) as usize;
        let submit = |ram: &mut RamStore, sector: u64, n: u64| {
            ram.write(off + 0x100, 8, 0);
            ram.write(off + 0x108, 8, sector);
            ram.write(off, 8, base + 0x100);
            ram.write(off + 8, 4, 16);
            ram.write(off + 12, 2, DESC_F_NEXT as u64);
            ram.write(off + 14, 2, 1);
            ram.write(off + 16, 8, base + 0x200);
            ram.write(off + 24, 4, 512);
            ram.write(off + 28, 2, (DESC_F_NEXT | DESC_F_WRITE) as u64);
            ram.write(off + 30, 2, 2);
            ram.write(off + 32, 8, base + 0x120);
            ram.write(off + 40, 4, 1);
            ram.write(off + 44, 2, DESC_F_WRITE as u64);
            ram.write(off + 0x80 + 4 + 2 * ((n as usize - 1) % 8), 2, 0);
            ram.write(off + 0x80 + 2, 2, n);
        };

        // Transient injected error: one request fails with IOERR status,
        // the retry succeeds — the guest driver's retry-once heals it.
        b.fault_error_n = 1;
        submit(&mut ram, 5, 1);
        b.write(REG_NOTIFY, 4, 0);
        b.service(&mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.read(off + 0xc0 + 2, 2), 1, "forced error still completes");
        assert_eq!(ram.read(off + 0x120, 1), 2, "forced IOERR status");
        assert_eq!((b.errors, b.fault_error_n), (1, 0));
        submit(&mut ram, 5, 2);
        b.write(REG_NOTIFY, 4, 0);
        b.service(&mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.read(off + 0x120, 1), 0, "fault consumed: retry succeeds");
        assert_eq!(ram.read(off + 0x200, 1) as u8, blk_image_byte(5 * BLK_SECTOR_SIZE));

        // Injected hang: the device is frozen — notify is latched but no
        // used-ring write, no IRQ — until the fault is lifted.
        b.fault_wedge = true;
        submit(&mut ram, 6, 3);
        b.write(REG_NOTIFY, 4, 0);
        for _ in 0..10 {
            b.service(&mut ram, &mut code, &mut plic, &mut ev);
        }
        assert_eq!(ram.read(off + 0xc0 + 2, 2), 2, "wedged device never completes");
        // A guest-side device reset must not clear the injected faults.
        b.write(REG_STATUS, 4, 0);
        assert!(b.fault_wedge, "guest reset cannot clear an injected wedge");
        b.fault_wedge = false;
        b.write(REG_QUEUE_NUM, 4, VIRTQ_SIZE as u64);
        b.write(REG_DESC, 8, base);
        b.write(REG_AVAIL, 8, base + 0x80);
        b.write(REG_USED, 8, base + 0xc0);
        b.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        submit(&mut ram, 6, 3);
        b.write(REG_NOTIFY, 4, 0);
        b.service(&mut ram, &mut code, &mut plic, &mut ev);
        assert_eq!(ram.read(off + 0x120, 1), 0, "healed device serves again");
    }

    #[test]
    fn injected_queue_wedge_freezes_delivery() {
        let (mut ram, mut code, mut plic, mut ev) = parts();
        let mut q = VirtioQueue::new();
        program(&mut q, &mut ram, RAM_BASE + 0x1000);
        q.write(REG_SEED, 8, 11);
        q.write(REG_REQ_TOTAL, 4, 2);
        q.write(REG_STATUS, 4, STATUS_DRIVER_OK as u64);
        q.fault_wedge = true;
        for t in 1..200u64 {
            q.service(t * 100, &mut ram, &mut code, &mut plic, &mut ev);
        }
        assert_eq!(ram.read(0x1000 + 0xc0 + 2, 2), 0, "wedged queue delivers nothing");
        assert_eq!(q.generated, 0, "wedged queue generates nothing");
        q.fault_wedge = false;
        for t in 200..400u64 {
            q.service(t * 100, &mut ram, &mut code, &mut plic, &mut ev);
        }
        assert!(ram.read(0x1000 + 0xc0 + 2, 2) > 0, "lifted wedge resumes delivery");
    }
}
