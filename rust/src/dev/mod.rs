//! Platform devices: CLINT (timer + software interrupts), a UART console,
//! a minimal PLIC, and the paravirtual virtio-MMIO family ([`virtio`]).
//! These are the substrate the guest software stack needs (the paper's
//! §3.5 device-tree discussion maps to this fixed Spike-like platform
//! layout).

mod clint;
mod plic;
mod uart;
pub mod virtio;

pub use clint::Clint;
pub use plic::Plic;
pub use uart::Uart;
pub use virtio::{DevEvent, VirtioBlk, VirtioQueue};

/// A memory-mapped device behind the [`Bus`](crate::mem::Bus)
/// registration table. `off` is the offset within the device's
/// registered aperture; `size` is the access width in bytes (1/2/4/8).
///
/// Handlers must be pure register-state machines: no guest-RAM DMA and
/// no interrupt-line changes from inside an MMIO access. Devices with
/// ring traffic (virtio) latch doorbells here and do the actual work in
/// their `service` hook, which `Machine::device_update` drives on the
/// node timebase — keeping the DESIGN.md §19 invariant that device
/// state reaches `mip` in exactly one place.
pub trait MmioDevice {
    fn read(&mut self, off: u64, size: u64) -> u64;
    fn write(&mut self, off: u64, size: u64, val: u64);
}

impl MmioDevice for Clint {
    fn read(&mut self, off: u64, size: u64) -> u64 {
        Clint::read(self, off, size)
    }
    fn write(&mut self, off: u64, size: u64, val: u64) {
        Clint::write(self, off, size, val)
    }
}

impl MmioDevice for Uart {
    fn read(&mut self, off: u64, _size: u64) -> u64 {
        Uart::read(self, off)
    }
    fn write(&mut self, off: u64, _size: u64, val: u64) {
        Uart::write(self, off, val as u8)
    }
}

impl MmioDevice for Plic {
    fn read(&mut self, off: u64, _size: u64) -> u64 {
        Plic::read(self, off)
    }
    fn write(&mut self, off: u64, _size: u64, val: u64) {
        Plic::write(self, off, val)
    }
}
