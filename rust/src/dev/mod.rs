//! Platform devices: CLINT (timer + software interrupts), a UART console,
//! and a minimal PLIC. These are the substrate the guest software stack
//! needs (the paper's §3.5 device-tree discussion maps to this fixed
//! Spike-like platform layout).

mod clint;
mod plic;
mod uart;

pub use clint::Clint;
pub use plic::Plic;
pub use uart::Uart;
