//! Small self-contained utilities: a dependency-free SHA-256 and the
//! [`ConsoleDigest`] the fleet layer streams guest consoles into.
//!
//! The fleet layer used to retain every guest's full console `String` in
//! its report; at hundreds of nodes that is O(fleet) live strings for a
//! byte-equality check. A console is now summarized as a rolling SHA-256
//! over the full stream plus a bounded tail (for human diagnostics) —
//! equality of (`sha256`, `len`, `tail`) is the fleet's console-vs-solo
//! oracle.

/// Bytes of console tail retained for diagnostics (and for the bounded
/// buffer the streaming UART keeps).
pub const CONSOLE_TAIL: usize = 256;

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256 (FIPS 180-4). `Clone` is cheap, so a rolling
/// hasher can be snapshotted to produce a digest mid-stream.
#[derive(Clone, Debug)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            } else {
                // Chunk fully absorbed without filling the block; the
                // trailing store below must not clobber buf_len.
                return;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        self.buf[..data.len()].copy_from_slice(data);
        self.buf_len = data.len();
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bits = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // The length update must not recount padding: bypass update().
        self.buf[56..64].copy_from_slice(&bits.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, c) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(c.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }

    /// One-shot convenience.
    pub fn digest(data: &[u8]) -> [u8; 32] {
        let mut h = Sha256::new();
        h.update(data);
        h.finalize()
    }
}

/// Streaming summary of a console: SHA-256 over the full byte stream,
/// total length, and the last [`CONSOLE_TAIL`] bytes for diagnostics.
/// Equality means "byte-identical stream" (modulo SHA-256 collisions).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConsoleDigest {
    pub sha256: [u8; 32],
    pub len: u64,
    pub tail: String,
}

impl ConsoleDigest {
    /// Digest a fully-retained console (solo baselines take this path;
    /// streamed fleet guests produce the same value incrementally).
    pub fn of_bytes(bytes: &[u8]) -> ConsoleDigest {
        let tail_at = bytes.len().saturating_sub(CONSOLE_TAIL);
        ConsoleDigest {
            sha256: Sha256::digest(bytes),
            len: bytes.len() as u64,
            tail: String::from_utf8_lossy(&bytes[tail_at..]).into_owned(),
        }
    }

    /// Lowercase hex of the SHA-256.
    pub fn hex(&self) -> String {
        self.sha256.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// Short hex prefix for reports.
    pub fn short_hex(&self) -> String {
        self.hex()[..12].to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: [u8; 32]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            hex(Sha256::digest(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(Sha256::digest(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(Sha256::digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn incremental_updates_match_one_shot() {
        // Cover every buffer-boundary case: sub-block, exactly-one-block,
        // and straddling chunk sizes.
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let want = Sha256::digest(&data);
        for chunk in [1usize, 3, 63, 64, 65, 127, 997] {
            let mut h = Sha256::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finalize(), want, "chunk size {chunk}");
        }
        // 55/56/64-byte messages hit the padding edge cases.
        for n in [55usize, 56, 63, 64] {
            let mut h = Sha256::new();
            h.update(&data[..n]);
            assert_eq!(h.finalize(), Sha256::digest(&data[..n]), "len {n}");
        }
    }

    #[test]
    fn snapshot_hasher_resumes() {
        let mut h = Sha256::new();
        h.update(b"hello ");
        let snap = h.clone();
        h.update(b"world");
        assert_eq!(h.finalize(), Sha256::digest(b"hello world"));
        let mut h2 = snap;
        h2.update(b"fleet");
        assert_eq!(h2.finalize(), Sha256::digest(b"hello fleet"));
    }

    #[test]
    fn console_digest_tail_and_equality() {
        let short = ConsoleDigest::of_bytes(b"ok\n");
        assert_eq!(short.tail, "ok\n");
        assert_eq!(short.len, 3);
        let long: Vec<u8> = (0..1000).map(|i| b'a' + (i % 26) as u8).collect();
        let d = ConsoleDigest::of_bytes(&long);
        assert_eq!(d.tail.len(), CONSOLE_TAIL);
        assert_eq!(d.tail.as_bytes(), &long[1000 - CONSOLE_TAIL..]);
        assert_ne!(d, ConsoleDigest::of_bytes(&long[..999]));
        assert_eq!(d, ConsoleDigest::of_bytes(&long));
        assert_eq!(d.short_hex().len(), 12);
    }
}
