//! hvsim — CLI launcher.
//!
//! ```text
//! hvsim run   [--bench NAME] [--vm] [--scale N] [--config FILE]
//!             [--stats] [--echo] [--max-ticks N]
//! hvsim sweep [--scale N] [--config FILE] [--trace] [--out FILE]
//! hvsim vmm   [--guests N] [--slice T] [--bench A,B] [--scale N]
//!             [--policy all|vmid|none] [--out FILE]
//! hvsim fleet [--nodes M] [--guests N] [--threads K] [--slice T]
//!             [--bench A,B] [--scale N] [--policy all|vmid|none]
//!             [--out FILE]
//! hvsim timing [--bench NAME] [--vm] [--scale N] [--artifacts DIR]
//! hvsim boot  [--config FILE]
//! hvsim list
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use hvsim::config::SimConfig;
use hvsim::coordinator;
use hvsim::runtime::TimingEngine;
use hvsim::sim::ExitReason;
use hvsim::sw;

struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}'");
            };
            // boolean flags
            if matches!(name, "vm" | "stats" | "echo" | "trace") {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let val = argv.get(i + 1).with_context(|| format!("--{name} needs a value"))?;
            flags.insert(name.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }
    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
    fn u64(&self, k: &str) -> Result<Option<u64>> {
        self.get(k).map(|v| v.parse().with_context(|| format!("--{k}={v}"))).transpose()
    }
}

fn load_cfg(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::from_file(&PathBuf::from(path))?,
        None => SimConfig::default(),
    };
    if let Some(b) = args.get("bench") {
        cfg.workload = b.to_string();
    }
    if args.has("vm") {
        cfg.vm = true;
    }
    if let Some(s) = args.u64("scale")? {
        cfg.scale = s;
    }
    if let Some(t) = args.u64("max-ticks")? {
        cfg.max_ticks = t;
    }
    if args.has("echo") {
        cfg.uart_echo = true;
    }
    Ok(cfg)
}

/// Shared `--policy` parsing for the vmm/fleet subcommands.
fn parse_policy(args: &Args) -> Result<hvsim::vmm::FlushPolicy> {
    Ok(match args.get("policy") {
        None => hvsim::vmm::FlushPolicy::Partitioned,
        Some(p) => hvsim::vmm::FlushPolicy::parse(p)
            .with_context(|| format!("unknown --policy '{p}' (all|vmid|none)"))?,
    })
}

/// Shared `--bench` parsing (comma-separated mix, two distinct guest
/// kernels interleave by default) for the vmm/fleet subcommands.
fn parse_benches(args: &Args) -> Result<Vec<String>> {
    let arg = args.get("bench").unwrap_or("qsort,bitcount");
    let benches: Vec<String> =
        arg.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
    if benches.is_empty() {
        bail!("--bench must name at least one benchmark");
    }
    Ok(benches)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let mut m = cfg.build_machine();
    if cfg.vm {
        sw::setup_guest(&mut m, &cfg.workload, cfg.scale)?;
    } else {
        sw::setup_native(&mut m, &cfg.workload, cfg.scale)?;
    }
    let r = m.run(cfg.max_ticks);
    if !cfg.uart_echo {
        print!("{}", m.console());
    }
    match r {
        ExitReason::PowerOff(code) if code == hvsim::mem::SYSCON_PASS => {
            eprintln!(
                "[hvsim] {} ({}) ok: {} insts, {} ticks, {:.3}s host",
                cfg.workload,
                if cfg.vm { "guest" } else { "native" },
                m.stats.sim_insts,
                m.stats.sim_ticks,
                m.stats.host_time.as_secs_f64()
            );
        }
        other => bail!("run failed: {other:?}"),
    }
    if args.has("stats") {
        println!("{}", m.stats_txt());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let with_trace = args.has("trace");
    let mut pairs = coordinator::sweep(&cfg, &sw::BENCHMARKS, with_trace)?;
    coordinator::retime_sequential(&cfg, &mut pairs, 3)?;
    let pairs = pairs;
    let mut out = String::new();
    out.push_str(&coordinator::fig4_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig5_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig6_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig7_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::boot_table(&pairs));
    let bad = coordinator::check_paper_claims(&pairs);
    out.push('\n');
    if bad.is_empty() {
        out.push_str("paper-claims check: ALL HOLD\n");
    } else {
        out.push_str("paper-claims check: VIOLATIONS\n");
        for b in &bad {
            out.push_str(&format!("  - {b}\n"));
        }
    }
    if with_trace {
        let dir = args
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(TimingEngine::default_dir);
        let mut eng = TimingEngine::load(&dir)?;
        let mut rows = Vec::new();
        for p in &pairs {
            for r in [&p.native, &p.guest] {
                if let Some(tr) = &r.trace {
                    eng.reset();
                    rows.push((r.name.clone(), r.vm, eng.analyze(tr)?));
                }
            }
        }
        out.push('\n');
        out.push_str(&coordinator::timing_table(&rows));
    }
    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !bad.is_empty() {
        bail!("{} paper claims violated", bad.len());
    }
    Ok(())
}

/// The consolidation sweep: 1/2/4/…/N guests time-sliced onto one hart.
fn cmd_vmm(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let max_guests = args.u64("guests")?.unwrap_or(4).max(1) as usize;
    let slice = args.u64("slice")?.unwrap_or(200_000).max(1);
    let policy = parse_policy(args)?;
    let benches_owned = parse_benches(args)?;
    let benches: Vec<&str> = benches_owned.iter().map(String::as_str).collect();
    // Guest counts: powers of two up to N, plus N itself.
    let mut counts = Vec::new();
    let mut c = 1usize;
    while c <= max_guests {
        counts.push(c);
        c *= 2;
    }
    if *counts.last().unwrap() != max_guests {
        counts.push(max_guests);
    }

    let rows = coordinator::consolidation_sweep(&cfg, &benches, &counts, slice, policy)?;
    let mut out = coordinator::consolidation_table(&rows, &benches);
    let all_ok = rows.iter().all(|r| r.all_passed && r.checksums_ok);
    out.push('\n');
    if all_ok {
        out.push_str("consolidation check: ALL GUESTS POWERED OFF PASS, CHECKSUMS MATCH SOLO\n");
    } else {
        out.push_str("consolidation check: FAILURES\n");
    }
    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !all_ok {
        bail!("consolidation sweep failed");
    }
    Ok(())
}

/// The fleet experiment: M consolidated nodes × N guests sharded across K
/// host threads, with checkpoint-forked construction, a 1-thread baseline
/// for the parallel-speedup figure, and a console-vs-solo byte check.
fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let nodes = args.u64("nodes")?.unwrap_or(2).max(1) as usize;
    let guests = args.u64("guests")?.unwrap_or(2).max(1) as usize;
    let threads = match args.u64("threads")? {
        Some(t) => t.max(1) as usize,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(nodes),
    };
    let slice = args.u64("slice")?.unwrap_or(200_000).max(1);
    let policy = parse_policy(args)?;
    let benches = parse_benches(args)?;
    let spec = hvsim::fleet::FleetSpec {
        nodes,
        guests_per_node: guests,
        threads,
        slice_ticks: slice,
        policy,
        benches,
        scale: cfg.scale,
        ram_bytes: coordinator::GUEST_NODE_RAM,
        max_node_ticks: cfg.max_ticks.saturating_mul(guests as u64),
        tlb_sets: cfg.tlb_sets as usize,
        tlb_ways: cfg.tlb_ways as usize,
    };

    // Full per-guest construction cost, for the checkpoint-fork
    // comparison. Counted in firmware+kernel assemblies only: the per-VMID
    // hypervisor image cache serves both construction paths, so including
    // its (cache-order-dependent) assemblies would skew whichever pass
    // runs second. Nodes are identical, so one full node is built and
    // extrapolated ×M — paying the whole O(M·N) assembly bill here would
    // defeat the optimization being measured. Counters are exact: the CLI
    // is single-threaded outside the run phase.
    let bench_refs: Vec<&str> = spec.benches.iter().map(String::as_str).collect();
    let fw_kernel_delta = |asm0: u64, hv0: u64| {
        (hvsim::sw::assembly_count() - asm0) - (hvsim::sw::hv_assembly_count() - hv0)
    };
    let (asm0, hv0) = (hvsim::sw::assembly_count(), hvsim::sw::hv_assembly_count());
    let t0 = std::time::Instant::now();
    let node = hvsim::vmm::build_node(&bench_refs, spec.scale, guests, spec.ram_bytes)?;
    drop(node);
    let full_construct = (
        t0.elapsed().as_secs_f64() * spec.nodes as f64,
        fw_kernel_delta(asm0, hv0) * spec.nodes as u64,
    );

    let (asm1, hv1) = (hvsim::sw::assembly_count(), hvsim::sw::hv_assembly_count());
    let mut report = hvsim::fleet::run_fleet(&spec)?;
    // Replace the factory's conservative upper bound with the exact
    // firmware+kernel assembly count of this construction (execution
    // assembles nothing).
    report.construct_assemblies = fw_kernel_delta(asm1, hv1);
    // 1-thread baseline of the same fleet for the host-speedup figure
    // (report.threads is already clamped to the node count, so a 1-node
    // fleet never re-runs as its own baseline).
    let baseline = if report.threads > 1 {
        let mut solo = spec.clone();
        solo.threads = 1;
        Some(hvsim::fleet::run_fleet(&solo)?)
    } else {
        None
    };
    // Solo baselines: every fleet guest's console must be byte-identical.
    let solos = hvsim::fleet::solo_consoles(&spec)?;
    let mismatches = hvsim::fleet::console_mismatches(&report, &solos);

    let out = coordinator::fleet_table(
        &spec,
        &report,
        baseline.as_ref(),
        Some(full_construct),
        &mismatches,
    );
    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !report.all_passed() {
        bail!("fleet run failed: not all guests passed");
    }
    if !mismatches.is_empty() {
        bail!("fleet run failed: {} console(s) diverged from solo runs", mismatches.len());
    }
    if spec.total_guests() > spec.benches.len() && report.construct_assemblies >= full_construct.1 {
        bail!(
            "checkpoint-forked construction not cheaper: {} vs {} assemblies",
            report.construct_assemblies,
            full_construct.1
        );
    }
    Ok(())
}

fn cmd_timing(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(TimingEngine::default_dir);
    let mut eng = TimingEngine::load(&dir)?;
    let res = coordinator::run_one(&cfg, &cfg.workload, cfg.vm, true)?;
    let trace = res.trace.context("no trace captured")?;
    let rep = eng.analyze(&trace)?;
    println!(
        "{} ({}): refs={} dropped={} tlb-miss={:.3}% modeled-translation-overhead={:.4}x",
        cfg.workload,
        if cfg.vm { "guest" } else { "native" },
        rep.refs,
        trace.dropped,
        100.0 * rep.miss_rate(),
        rep.overhead_ratio()
    );
    Ok(())
}

fn cmd_boot(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let pairs = coordinator::sweep(&cfg, &[cfg.workload.as_str()], false)?;
    print!("{}", coordinator::boot_table(&pairs));
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "hvsim — gem5-style RISC-V simulator with the H extension\n\
         usage:\n  hvsim run   [--bench NAME] [--vm] [--scale N] [--config FILE] [--stats] [--echo]\n  \
         hvsim sweep [--scale N] [--trace] [--out FILE]\n  \
         hvsim vmm   [--guests N] [--slice T] [--bench A,B] [--policy all|vmid|none]\n  \
         hvsim fleet [--nodes M] [--guests N] [--threads K] [--slice T] [--bench A,B] [--policy all|vmid|none]\n  \
         hvsim timing [--bench NAME] [--vm] [--scale N] [--artifacts DIR]\n  \
         hvsim boot  [--bench NAME]\n  hvsim list"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "vmm" => cmd_vmm(&args),
        "fleet" => cmd_fleet(&args),
        "timing" => cmd_timing(&args),
        "boot" => cmd_boot(&args),
        "list" => {
            for b in sw::BENCHMARKS {
                println!("{b}");
            }
            Ok(())
        }
        _ => usage(),
    }
}
