//! hvsim — CLI launcher.
//!
//! ```text
//! hvsim run   [--bench NAME] [--vm] [--scale N] [--config FILE]
//!             [--stats] [--echo] [--max-ticks N] [--engine block|tick]
//!             [--trace-out F] [--metrics-out F] [--events-out F]
//! hvsim sweep [--scale N] [--config FILE] [--trace] [--out FILE]
//! hvsim vmm   [--guests N] [--harts H] [--slice T] [--bench A,B]
//!             [--workload kv|echo] [--scale N]
//!             [--policy all|vmid|none] [--sched rr|slo|weighted:W,...|gang]
//!             [--slo BENCH=TICKS,...] [--engine block|tick] [--out FILE]
//!             [--trace-out F] [--metrics-out F] [--events-out F]
//! hvsim fleet [--nodes M] [--guests N] [--harts H] [--threads K] [--slice T]
//!             [--bench A,B] [--workload kv|echo] [--rate R] [--scale N]
//!             [--policy all|vmid|none]
//!             [--sched rr|slo|weighted:W,...|gang] [--slo BENCH=TICKS,...]
//!             [--chaos SPEC] [--watchdog T] [--snap-every N]
//!             [--max-restarts R] [--strict] [--chaos-out F]
//!             [--engine block|tick] [--out FILE] [--requests-out F]
//!             [--trace-out F] [--metrics-out F] [--events-out F]
//! hvsim timing [--bench NAME] [--vm] [--scale N] [--artifacts DIR]
//! hvsim boot  [--config FILE]
//! hvsim fuzz  [--seed S] [--insts N] [--engine block|tick] [--selfcheck]
//!             [--prog FILE] [--prog-out FILE] [--trace-out FILE]
//! hvsim conform [--engine block|tick|both] [--suite NAME]
//! hvsim list
//! ```
//!
//! Telemetry (DESIGN.md §20) is enabled iff any of the three output
//! flags is present: `--trace-out` writes Chrome Trace Event JSON
//! (chrome://tracing / Perfetto), `--metrics-out` the merged counter
//! snapshot, `--events-out` the JSONL event stream.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use hvsim::config::SimConfig;
use hvsim::coordinator;
use hvsim::runtime::TimingEngine;
use hvsim::sim::ExitReason;
use hvsim::sw;
use hvsim::vmm::SchedKind;

struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}'");
            };
            // boolean flags
            if matches!(name, "vm" | "stats" | "echo" | "trace" | "selfcheck" | "strict") {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let val = argv.get(i + 1).with_context(|| format!("--{name} needs a value"))?;
            flags.insert(name.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }
    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
    fn u64(&self, k: &str) -> Result<Option<u64>> {
        self.get(k).map(|v| v.parse().with_context(|| format!("--{k}={v}"))).transpose()
    }
}

fn load_cfg(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::from_file(&PathBuf::from(path))?,
        None => SimConfig::default(),
    };
    if let Some(b) = args.get("bench") {
        cfg.workload = b.to_string();
    }
    if args.has("vm") {
        cfg.vm = true;
    }
    if let Some(s) = args.u64("scale")? {
        cfg.scale = s;
    }
    if let Some(t) = args.u64("max-ticks")? {
        cfg.max_ticks = t;
    }
    if args.has("echo") {
        cfg.uart_echo = true;
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = e.parse().context("bad --engine")?;
    }
    Ok(cfg)
}

/// Shared `--policy` (TLB flush) parsing for the vmm/fleet subcommands.
/// The `FromStr` error names the valid choices.
fn parse_policy(args: &Args) -> Result<hvsim::vmm::FlushPolicy> {
    match args.get("policy") {
        None => Ok(hvsim::vmm::FlushPolicy::Partitioned),
        Some(p) => p.parse().context("bad --policy"),
    }
}

/// Shared `--sched` (scheduling policy) parsing for the vmm/fleet
/// subcommands. The `FromStr` error names the valid choices.
fn parse_sched(args: &Args) -> Result<hvsim::vmm::SchedKind> {
    match args.get("sched") {
        None => Ok(hvsim::vmm::SchedKind::RoundRobin),
        Some(s) => s.parse().context("bad --sched"),
    }
}

/// Shared `--harts` (simulated harts per node) parsing for the vmm/fleet
/// subcommands; falls back to the config's `sim.harts` key (default 1).
/// Like `--sched`/`--policy`, the error spells out what is accepted.
fn parse_harts(args: &Args, cfg: &SimConfig) -> Result<usize> {
    match args.get("harts") {
        None => Ok(cfg.harts.max(1) as usize),
        Some(v) => match v.parse::<usize>() {
            Ok(h) if h >= 1 => Ok(h),
            _ => bail!("bad --harts '{v}' (expected a positive hart count: 1, 2, 4, ...)"),
        },
    }
}

/// Validate `--slo` overrides against the benchmark mix and fold them
/// into an SLO scheduling policy. Explicit targets win over the
/// fair-share defaulting applied later ([`SchedKind::fill_fair_share`]
/// only fills missing benchmarks). Shared by the vmm and fleet
/// subcommands so the `--slo` rules cannot diverge.
fn apply_slo_overrides(
    sched: &mut SchedKind,
    overrides: std::collections::BTreeMap<String, u64>,
    benches: &[String],
) -> Result<()> {
    if overrides.is_empty() {
        return Ok(());
    }
    for bench in overrides.keys() {
        if !benches.contains(bench) {
            bail!("--slo names unknown benchmark '{bench}' (mix: {})", benches.join(","));
        }
    }
    match sched {
        SchedKind::SloDeadline { targets } => {
            targets.extend(overrides);
            Ok(())
        }
        _ => bail!("--slo requires --sched slo"),
    }
}

/// Optional `--slo bench=ticks,bench=ticks` latency targets for
/// `--sched slo` (unset benchmarks fall back to solo-derived fair-share
/// targets in the fleet subcommand).
fn parse_slo_targets(args: &Args) -> Result<std::collections::BTreeMap<String, u64>> {
    let mut targets = std::collections::BTreeMap::new();
    if let Some(spec) = args.get("slo") {
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let (bench, ticks) = item
                .split_once('=')
                .with_context(|| format!("--slo entry '{item}' is not bench=ticks"))?;
            let ticks: u64 =
                ticks.parse().with_context(|| format!("--slo entry '{item}': bad tick count"))?;
            targets.insert(bench.to_string(), ticks);
        }
    }
    Ok(targets)
}

/// Shared `--bench` parsing (comma-separated mix, two distinct guest
/// kernels interleave by default) for the vmm/fleet subcommands.
/// `--workload kv|echo` (comma list) folds the request-serving guest
/// kernels (DESIGN.md §22) into the mix: alone it *is* the mix, alongside
/// `--bench` it extends it.
fn parse_benches(args: &Args) -> Result<Vec<String>> {
    let mut workloads = Vec::new();
    if let Some(spec) = args.get("workload") {
        for w in spec.split(',').filter(|s| !s.is_empty()) {
            workloads.push(match w {
                "kv" | "kvstore" => "kvstore".to_string(),
                "echo" => "echo".to_string(),
                other => bail!("unknown --workload '{other}' (expected kv, echo)"),
            });
        }
        if workloads.is_empty() {
            bail!("--workload must name at least one workload");
        }
    }
    let arg = match args.get("bench") {
        Some(b) => b,
        None if !workloads.is_empty() => "",
        None => "qsort,bitcount",
    };
    let mut benches: Vec<String> =
        arg.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
    benches.extend(workloads);
    if benches.is_empty() {
        bail!("--bench must name at least one benchmark");
    }
    Ok(benches)
}

/// Shared `--rate` parsing: open-loop request arrivals per simulated
/// second on every guest's paravirtual queue device. Only the
/// request-serving workloads consume it.
fn parse_rate(args: &Args) -> Result<u64> {
    Ok(args.u64("rate")?.unwrap_or(1_000_000).max(1))
}

/// The shared `--trace-out` / `--metrics-out` / `--events-out` telemetry
/// plumbing of the run/vmm/fleet subcommands: any present flag enables
/// event capture; each writes one export format from the same frozen
/// per-node timelines.
struct TelemetryOut {
    trace: Option<PathBuf>,
    metrics: Option<PathBuf>,
    events: Option<PathBuf>,
}

impl TelemetryOut {
    fn parse(args: &Args) -> TelemetryOut {
        TelemetryOut {
            trace: args.get("trace-out").map(PathBuf::from),
            metrics: args.get("metrics-out").map(PathBuf::from),
            events: args.get("events-out").map(PathBuf::from),
        }
    }

    fn enabled(&self) -> bool {
        self.trace.is_some() || self.metrics.is_some() || self.events.is_some()
    }

    fn cfg(&self) -> Option<hvsim::telemetry::TelemetryCfg> {
        self.enabled().then(hvsim::telemetry::TelemetryCfg::default)
    }

    fn write(&self, nodes: &[hvsim::telemetry::NodeTelemetry]) -> Result<()> {
        let mut emit = |path: &Option<PathBuf>, text: String| -> Result<()> {
            if let Some(p) = path {
                std::fs::write(p, text).with_context(|| format!("writing {}", p.display()))?;
            }
            Ok(())
        };
        emit(&self.trace, hvsim::telemetry::chrome::chrome_trace(nodes))?;
        emit(&self.metrics, hvsim::telemetry::counters::metrics_json(nodes))?;
        emit(&self.events, hvsim::telemetry::write_jsonl(nodes))?;
        Ok(())
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let tele = TelemetryOut::parse(args);
    let mut m = cfg.build_machine();
    if cfg.vm {
        sw::setup_guest(&mut m, &cfg.workload, cfg.scale)?;
    } else {
        sw::setup_native(&mut m, &cfg.workload, cfg.scale)?;
    }
    if let Some(tcfg) = tele.cfg() {
        m.enable_telemetry(0, tcfg.ring_cap);
        if let Some(t) = m.telemetry.as_mut() {
            t.label = format!("{} ({})", cfg.workload, if cfg.vm { "guest" } else { "native" });
        }
    }
    let r = m.run(cfg.max_ticks);
    if !cfg.uart_echo {
        print!("{}", m.console());
    }
    match r {
        ExitReason::PowerOff(code) if code == hvsim::mem::SYSCON_PASS => {
            eprintln!(
                "[hvsim] {} ({}) ok: {} insts, {} ticks, {:.3}s host",
                cfg.workload,
                if cfg.vm { "guest" } else { "native" },
                m.stats.sim_insts,
                m.stats.sim_ticks,
                m.stats.host_time.as_secs_f64()
            );
        }
        other => bail!("run failed: {other:?}"),
    }
    if args.has("stats") {
        println!("{}", m.stats_txt());
    }
    if let Some(nt) = m.finish_telemetry() {
        eprint!("{}", coordinator::telemetry_table(std::slice::from_ref(&nt)));
        tele.write(std::slice::from_ref(&nt))?;
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let with_trace = args.has("trace");
    let mut pairs = coordinator::sweep(&cfg, &sw::BENCHMARKS, with_trace)?;
    coordinator::retime_sequential(&cfg, &mut pairs, 3)?;
    let pairs = pairs;
    let mut out = String::new();
    out.push_str(&coordinator::fig4_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig5_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig6_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::fig7_table(&pairs));
    out.push('\n');
    out.push_str(&coordinator::boot_table(&pairs));
    let bad = coordinator::check_paper_claims(&pairs);
    out.push('\n');
    if bad.is_empty() {
        out.push_str("paper-claims check: ALL HOLD\n");
    } else {
        out.push_str("paper-claims check: VIOLATIONS\n");
        for b in &bad {
            out.push_str(&format!("  - {b}\n"));
        }
    }
    if with_trace {
        let dir = args
            .get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(TimingEngine::default_dir);
        let mut eng = TimingEngine::load(&dir)?;
        let mut rows = Vec::new();
        for p in &pairs {
            for r in [&p.native, &p.guest] {
                if let Some(tr) = &r.trace {
                    eng.reset();
                    rows.push((r.name.clone(), r.vm, eng.analyze(tr)?, tr.dropped));
                }
            }
        }
        out.push('\n');
        out.push_str(&coordinator::timing_table(&rows));
    }
    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !bad.is_empty() {
        bail!("{} paper claims violated", bad.len());
    }
    Ok(())
}

/// The consolidation sweep: 1/2/4/…/N guests time-sliced onto H harts.
fn cmd_vmm(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let max_guests = args.u64("guests")?.unwrap_or(4).max(1) as usize;
    let harts = parse_harts(args, &cfg)?;
    let slice = args.u64("slice")?.unwrap_or(200_000).max(1);
    let policy = parse_policy(args)?;
    let benches_owned = parse_benches(args)?;
    let benches: Vec<&str> = benches_owned.iter().map(String::as_str).collect();
    // Guest counts: powers of two up to N, plus N itself.
    let mut counts = Vec::new();
    let mut c = 1usize;
    while c <= max_guests {
        counts.push(c);
        c *= 2;
    }
    if *counts.last().unwrap() != max_guests {
        counts.push(max_guests);
    }

    let mut sched = parse_sched(args)?;
    apply_slo_overrides(&mut sched, parse_slo_targets(args)?, &benches_owned)?;
    let tele = TelemetryOut::parse(args);
    let (rows, tnodes) = coordinator::consolidation_sweep(
        &cfg,
        &benches,
        &counts,
        harts,
        slice,
        policy,
        &sched,
        tele.cfg(),
    )?;
    let mut out = coordinator::consolidation_table(&rows, &benches, &sched);
    let all_ok = rows.iter().all(|r| r.all_passed && r.checksums_ok);
    out.push('\n');
    if all_ok {
        out.push_str("consolidation check: ALL GUESTS POWERED OFF PASS, CHECKSUMS MATCH SOLO\n");
    } else {
        out.push_str("consolidation check: FAILURES\n");
    }
    if !tnodes.is_empty() {
        out.push('\n');
        out.push_str(&coordinator::telemetry_table(&tnodes));
        tele.write(&tnodes)?;
    }
    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !all_ok {
        bail!("consolidation sweep failed");
    }
    Ok(())
}

/// The fleet experiment: M consolidated nodes × N guests sharded across K
/// host threads, with checkpoint-forked construction, a 1-thread baseline
/// for the parallel-speedup figure, and a console-vs-solo byte check.
fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let nodes = args.u64("nodes")?.unwrap_or(2).max(1) as usize;
    let guests = args.u64("guests")?.unwrap_or(2).max(1) as usize;
    let harts = parse_harts(args, &cfg)?;
    let threads = match args.u64("threads")? {
        Some(t) => t.max(1) as usize,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(nodes),
    };
    let slice = args.u64("slice")?.unwrap_or(200_000).max(1);
    let policy = parse_policy(args)?;
    let mut sched = parse_sched(args)?;
    let benches = parse_benches(args)?;
    apply_slo_overrides(&mut sched, parse_slo_targets(args)?, &benches)?;
    let tele = TelemetryOut::parse(args);
    // Chaos/recovery knobs. --chaos with no --watchdog gets a default
    // hang threshold (livelock faults would otherwise never be detected);
    // chaos or a watchdog gets a default snapshot cadence so recovery
    // does not have to replay from boot.
    let chaos = args
        .get("chaos")
        .map(|s| s.parse::<hvsim::fleet::chaos::ChaosSpec>())
        .transpose()
        .context("bad --chaos")?;
    let watchdog =
        args.u64("watchdog")?.unwrap_or(if chaos.is_some() { 2_000_000 } else { 0 });
    let resilient = chaos.is_some() || watchdog > 0;
    let snap_every =
        args.u64("snap-every")?.unwrap_or(if resilient { 500_000 } else { 0 });
    let max_restarts = args.u64("max-restarts")?.unwrap_or(3) as u32;
    let mut spec = hvsim::fleet::FleetSpec {
        nodes,
        guests_per_node: guests,
        threads,
        harts,
        slice_ticks: slice,
        policy,
        sched,
        benches,
        scale: cfg.scale,
        rate: parse_rate(args)?,
        ram_bytes: coordinator::GUEST_NODE_RAM,
        max_node_ticks: cfg.max_ticks.saturating_mul(guests as u64),
        tlb_sets: cfg.tlb_sets as usize,
        tlb_ways: cfg.tlb_ways as usize,
        engine: cfg.engine,
        telemetry: tele.cfg(),
        chaos,
        watchdog,
        snap_every,
        max_restarts,
        strict: args.has("strict"),
        expected: std::collections::BTreeMap::new(),
    };

    // Solo baselines up front: the byte-check oracle for every fleet
    // guest's console, and the work estimate the SLO scheduler's default
    // fair-share targets (solo ticks × guests per node) derive from.
    // Explicit --slo targets (already merged) win over the derived ones.
    let solos = hvsim::fleet::solo_baselines(&spec)?;
    spec.sched
        .fill_fair_share(solos.iter().map(|(b, s)| (b.as_str(), s.ticks)), guests as u64);
    // The recovery driver's divergence oracle: a guest that powers off
    // "passed" but with a console that differs from its solo run is a
    // failure to route into restore, exactly like a failed exit.
    if spec.resilience_active() && !spec.strict {
        spec.expected =
            solos.iter().map(|(k, v)| (k.clone(), v.digest.clone())).collect();
    }

    // Engine A/B smoke: the solo baselines re-run under the *other*
    // execution engine must be bit-exact — same console digest, same
    // completion tick. O(#benches), so the fleet smoke path carries a
    // standing cross-engine differential check (CI runs this).
    let engine_ab_line = {
        let mut alt = spec.clone();
        alt.engine = spec.engine.other();
        let alt_solos = hvsim::fleet::solo_baselines(&alt)?;
        for (bench, s) in &solos {
            let a = &alt_solos[bench];
            if a.digest != s.digest || a.ticks != s.ticks {
                bail!(
                    "engine A/B mismatch for {bench}: {} sha {} / {} ticks vs {} sha {} / {} ticks",
                    spec.engine.name(),
                    s.digest.short_hex(),
                    s.ticks,
                    alt.engine.name(),
                    a.digest.short_hex(),
                    a.ticks,
                );
            }
        }
        format!(
            "engine A/B ({} vs {}): {} solo console digest(s) + completion ticks identical\n",
            spec.engine.name(),
            alt.engine.name(),
            solos.len()
        )
    };

    // Full per-guest construction cost, for the checkpoint-fork
    // comparison. Counted in firmware+kernel assemblies only: the per-VMID
    // hypervisor image cache serves both construction paths, so including
    // its (cache-order-dependent) assemblies would skew whichever pass
    // runs second. Nodes are identical, so one full node is built and
    // extrapolated ×M — paying the whole O(M·N) assembly bill here would
    // defeat the optimization being measured. Counters are exact: the CLI
    // is single-threaded outside the run phase.
    let bench_refs: Vec<&str> = spec.benches.iter().map(String::as_str).collect();
    let fw_kernel_delta = |asm0: u64, hv0: u64| {
        (hvsim::sw::assembly_count() - asm0) - (hvsim::sw::hv_assembly_count() - hv0)
    };
    let (asm0, hv0) = (hvsim::sw::assembly_count(), hvsim::sw::hv_assembly_count());
    let t0 = std::time::Instant::now();
    let node = hvsim::vmm::build_node(&bench_refs, spec.scale, guests, spec.ram_bytes)?;
    drop(node);
    let full_construct = (
        t0.elapsed().as_secs_f64() * spec.nodes as f64,
        fw_kernel_delta(asm0, hv0) * spec.nodes as u64,
    );

    let (asm1, hv1) = (hvsim::sw::assembly_count(), hvsim::sw::hv_assembly_count());
    let mut report = hvsim::fleet::run_fleet(&spec)?;
    // Replace the factory's conservative upper bound with the exact
    // firmware+kernel assembly count of this construction (execution
    // assembles nothing).
    report.construct_assemblies = fw_kernel_delta(asm1, hv1);
    // 1-thread baseline of the same fleet for the host-speedup figure
    // (report.threads is already clamped to the node count, so a 1-node
    // fleet never re-runs as its own baseline).
    let baseline = if report.threads > 1 {
        let mut solo = spec.clone();
        solo.threads = 1;
        // The baseline exists for the speedup figure only — keep it
        // untelemetered so its rings don't shadow the measured fleet's.
        solo.telemetry = None;
        Some(hvsim::fleet::run_fleet(&solo)?)
    } else {
        None
    };
    // Every fleet guest's console must be byte-identical to its solo run
    // (checked by streaming digest: SHA-256 + length + tail).
    let solo_digests: std::collections::BTreeMap<String, hvsim::util::ConsoleDigest> =
        solos.iter().map(|(k, v)| (k.clone(), v.digest.clone())).collect();
    let mismatches = hvsim::fleet::console_mismatches(&report, &solo_digests);

    let mut out = coordinator::fleet_table(
        &spec,
        &report,
        baseline.as_ref(),
        Some(full_construct),
        &mismatches,
    );
    out.push_str(&engine_ab_line);

    // The SLO scheduler is compared against a round-robin run of the
    // identical fleet, and hard-bails if p99 regresses (CI smokes on
    // this). When the mix serves requests, the gated metric is *request*
    // p99 — the tail a cloud operator actually sells — instead of guest
    // completion ticks. Other non-RR policies skip the comparison — an
    // extra whole-fleet run is not worth one informational line, and
    // weighted-slice deliberately skews slices anyway.
    let mut p99_regressed = None;
    let mut p99_metric = "completion";
    if matches!(spec.sched, SchedKind::SloDeadline { .. }) {
        let mut rr_spec = spec.clone();
        rr_spec.sched = SchedKind::RoundRobin;
        rr_spec.telemetry = None;
        let rr = hvsim::fleet::run_fleet(&rr_spec)?;
        if rr.all_passed() {
            let requests = !report.request_latencies().is_empty();
            let pick = |r: &hvsim::fleet::FleetReport, q: f64| {
                if requests {
                    r.request_percentile(q).unwrap_or(0)
                } else {
                    r.latency_percentile(q).unwrap_or(0)
                }
            };
            if requests {
                p99_metric = "request";
            }
            let (p50, p99) = (pick(&report, 0.50), pick(&report, 0.99));
            let (rr_p50, rr_p99) = (pick(&rr, 0.50), pick(&rr, 0.99));
            out.push_str(&format!(
                "sched {} vs round-robin: {} p50 {} vs {} ({:+.2}%), p99 {} vs {} ({:+.2}%)\n",
                spec.sched.name(),
                p99_metric,
                p50,
                rr_p50,
                100.0 * (p50 as f64 - rr_p50 as f64) / rr_p50.max(1) as f64,
                p99,
                rr_p99,
                100.0 * (p99 as f64 - rr_p99 as f64) / rr_p99.max(1) as f64,
            ));
            if p99 > rr_p99 {
                p99_regressed = Some((p99, rr_p99));
            }
        } else {
            // Percentiles over a partially-finished baseline would compare
            // different populations; with the SLO fleet fully passed (or
            // bailing below on its own), a failing RR baseline means the
            // SLO run was no worse — skip the gate, say so.
            out.push_str(
                "sched slo-deadline vs round-robin: baseline did not finish within budget; \
                 p99 gate skipped\n",
            );
        }
    }

    // Telemetry exports + the counter cross-check: the event-derived
    // counters must agree bit-exactly with the independently maintained
    // scheduler/guest statistics, or the timeline cannot be trusted.
    let mut counter_bad = Vec::new();
    if tele.enabled() {
        let tnodes: Vec<hvsim::telemetry::NodeTelemetry> =
            report.nodes.iter().filter_map(|n| n.telemetry.clone()).collect();
        out.push('\n');
        out.push_str(&coordinator::telemetry_table(&tnodes));
        tele.write(&tnodes)?;
        counter_bad = hvsim::fleet::counter_mismatches(&report);
    }

    // Report-only request-latency export (CI uploads it as
    // BENCH_requests.json): fleet-wide and per-workload p50/p99 plus
    // served-request throughput. Ticks are nominal nanoseconds.
    if let Some(path) = args.get("requests-out") {
        let mut workloads = String::new();
        let mut seen: Vec<&str> = Vec::new();
        for g in report.guests() {
            if g.req_latencies.is_empty() || seen.contains(&g.bench.as_str()) {
                continue;
            }
            seen.push(&g.bench);
            let mut v: Vec<u64> = report
                .guests()
                .filter(|x| x.bench == g.bench)
                .flat_map(|x| x.req_latencies.iter().copied())
                .collect();
            v.sort_unstable();
            let pct =
                |q: f64| v[((q * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1];
            let completed: u64 = report
                .guests()
                .filter(|x| x.bench == g.bench)
                .map(|x| x.req_completed as u64)
                .sum();
            if !workloads.is_empty() {
                workloads.push_str(",\n");
            }
            workloads.push_str(&format!(
                "    {{\"workload\": \"{}\", \"completed\": {}, \"p50_ticks\": {}, \"p99_ticks\": {}}}",
                g.bench,
                completed,
                pct(0.50),
                pct(0.99)
            ));
        }
        let json = format!(
            "{{\n  \"schema\": \"hvsim-requests-v1\",\n  \"rate_per_sec\": {},\n  \
             \"nodes\": {},\n  \"guests\": {},\n  \"requests_completed\": {},\n  \
             \"request_errors\": {},\n  \"request_p50_ticks\": {},\n  \
             \"request_p99_ticks\": {},\n  \"requests_per_sim_sec\": {:.3},\n  \
             \"workloads\": [\n{}\n  ]\n}}\n",
            spec.rate,
            spec.nodes,
            spec.total_guests(),
            report.requests_completed(),
            report.request_errors(),
            report.request_percentile(0.50).unwrap_or(0),
            report.request_percentile(0.99).unwrap_or(0),
            report.requests_per_sim_sec(),
            workloads
        );
        std::fs::write(path, json).with_context(|| format!("writing {path}"))?;
    }

    // Chaos artifact (CI uploads it as BENCH_chaos.json): the modeled
    // availability/MTTR figures plus per-guest recovery accounting, all
    // bit-reproducible for a given --chaos seed.
    if let Some(path) = args.get("chaos-out") {
        let mut rows = String::new();
        for g in report.guests() {
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            rows.push_str(&format!(
                "    {{\"node\": {}, \"guest\": {}, \"bench\": \"{}\", \"passed\": {}, \
                 \"restarts\": {}, \"quarantined\": {}, \"downtime_ticks\": {}, \
                 \"console_sha\": \"{}\"}}",
                g.node,
                g.id,
                g.bench,
                g.passed,
                g.restarts,
                g.quarantined,
                g.downtime,
                g.console.short_hex(),
            ));
        }
        let json = format!(
            "{{\n  \"schema\": \"hvsim-chaos-v1\",\n  \"chaos\": \"{}\",\n  \
             \"watchdog_ticks\": {},\n  \"snap_every_ticks\": {},\n  \
             \"max_restarts\": {},\n  \"availability\": {:.6},\n  \"mttr_ticks\": {},\n  \
             \"restarts\": {},\n  \"quarantined\": {},\n  \"guests\": [\n{}\n  ]\n}}\n",
            spec.chaos.as_ref().map_or("off".to_string(), |c| c.summary()),
            spec.watchdog,
            spec.snap_every,
            spec.max_restarts,
            report.availability(),
            report.mttr().map_or("null".to_string(), |m| format!("{m:.1}")),
            report.total_restarts(),
            report.quarantined_guests(),
            rows
        );
        std::fs::write(path, json).with_context(|| format!("writing {path}"))?;
    }

    match args.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    if !counter_bad.is_empty() {
        bail!(
            "fleet run failed: telemetry counters diverged from scheduler stats:\n  {}",
            counter_bad.join("\n  ")
        );
    }
    if spec.resilience_active() && !spec.strict {
        // Graceful degradation: quarantined guests are reported above,
        // not fatal. Any *non*-quarantined failure means recovery did
        // not do its job — that still fails the run.
        let unhealthy: Vec<String> = report
            .guests()
            .filter(|g| !g.quarantined && !g.passed)
            .map(|g| format!("node {} guest {} ({})", g.node, g.id, g.bench))
            .collect();
        if !unhealthy.is_empty() {
            bail!(
                "fleet run failed: guest(s) failed without being recovered or quarantined:\n  {}",
                unhealthy.join("\n  ")
            );
        }
    } else if !report.all_passed() {
        bail!("fleet run failed: not all guests passed");
    }
    if !mismatches.is_empty() {
        bail!("fleet run failed: {} console(s) diverged from solo runs", mismatches.len());
    }
    if let Some((p99, rr_p99)) = p99_regressed {
        bail!(
            "fleet run failed: {} p99 {} latency {} regressed past round-robin {}",
            spec.sched.name(),
            p99_metric,
            p99,
            rr_p99
        );
    }
    if spec.total_guests() > spec.benches.len() && report.construct_assemblies >= full_construct.1 {
        bail!(
            "checkpoint-forked construction not cheaper: {} vs {} assemblies",
            report.construct_assemblies,
            full_construct.1
        );
    }
    // CoW acceptance gate: forked construction must materialize < 5% of
    // the template's pages per guest (a rebind touches only the
    // hypervisor-image pages; everything else rides shared frames). CI
    // smokes this at 128 nodes.
    if report.fork_page_fraction() >= 0.05 {
        bail!(
            "fleet construction not copy-on-write enough: {} pages across {} forks \
             is {:.2}% of the {}-page/guest template budget (gate: < 5%)",
            report.construct_pages_forked,
            report.construct_forks,
            100.0 * report.fork_page_fraction(),
            report.page_slots_per_guest
        );
    }
    Ok(())
}

fn cmd_timing(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let dir = args.get("artifacts").map(PathBuf::from).unwrap_or_else(TimingEngine::default_dir);
    let mut eng = TimingEngine::load(&dir)?;
    let res = coordinator::run_one(&cfg, &cfg.workload, cfg.vm, true)?;
    let trace = res.trace.context("no trace captured")?;
    let rep = eng.analyze(&trace)?;
    println!(
        "{} ({}): refs={} dropped={} tlb-miss={:.3}% modeled-translation-overhead={:.4}x",
        cfg.workload,
        if cfg.vm { "guest" } else { "native" },
        rep.refs,
        trace.dropped,
        100.0 * rep.miss_rate(),
        rep.overhead_ratio()
    );
    Ok(())
}

fn cmd_boot(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let pairs = coordinator::sweep(&cfg, &[cfg.workload.as_str()], false)?;
    print!("{}", coordinator::boot_table(&pairs));
    Ok(())
}

fn cmd_fuzz(args: &Args) -> Result<()> {
    use hvsim::fuzz::{self, Engine};
    let seed = args.u64("seed")?.unwrap_or(1);
    let insts = args.u64("insts")?.unwrap_or(100_000);
    let engine = match args.get("engine") {
        None => Engine::Block,
        Some(s) => Engine::parse(s).with_context(|| format!("--engine {s}: expected tick|block"))?,
    };
    let src = match args.get("prog") {
        Some(path) => std::fs::read_to_string(path).with_context(|| format!("--prog {path}"))?,
        None => fuzz::generate_program(seed, insts),
    };
    if let Some(path) = args.get("prog-out") {
        std::fs::write(path, &src).with_context(|| format!("--prog-out {path}"))?;
    }
    // The retired-instruction cap leaves generous room for trap handlers
    // and the loop tail beyond the requested body volume.
    let cap = insts.saturating_mul(6).saturating_add(500_000);
    if args.has("selfcheck") {
        match fuzz::selfcheck(&src, cap) {
            Ok((tick, block)) => {
                println!(
                    "selfcheck ok: tick and block agree over {} retired insts ({} traps, {} sync records)",
                    tick.retired,
                    tick.traps.len(),
                    block.syncs.len()
                );
                return Ok(());
            }
            Err(e) => {
                eprintln!("selfcheck DIVERGENCE (seed={seed}): {e}");
                std::process::exit(1);
            }
        }
    }
    let run = fuzz::run_program(&src, engine, cap).map_err(|e| anyhow::anyhow!(e))?;
    let trace = fuzz::trace_jsonl(&run);
    match args.get("trace-out") {
        Some(path) => std::fs::write(path, trace).with_context(|| format!("--trace-out {path}"))?,
        None => print!("{trace}"),
    }
    if run.poweroff.is_none() {
        bail!(
            "fuzz program did not power off within {cap} insts (retired {}) — likely a generator or engine bug",
            run.retired
        );
    }
    eprintln!(
        "fuzz seed={seed} engine={} retired={} traps={} syncs={}",
        engine.name(),
        run.retired,
        run.traps.len(),
        run.syncs.len()
    );
    Ok(())
}

fn cmd_conform(args: &Args) -> Result<()> {
    use hvsim::fuzz::{conformance, Engine};
    let engines = match args.get("engine") {
        None | Some("both") => vec![Engine::Tick, Engine::Block],
        Some(s) => {
            vec![Engine::parse(s).with_context(|| format!("--engine {s}: expected tick|block|both"))?]
        }
    };
    let filter = args.get("suite");
    let (mut total, mut failed) = (0usize, 0usize);
    for engine in engines {
        for r in conformance::run_all(filter, engine) {
            total += 1;
            if r.pass {
                println!("PASS {} ({}, {} insts)", r.name, r.engine.name(), r.retired);
            } else {
                failed += 1;
                println!("FAIL {} ({}): {}", r.name, r.engine.name(), r.detail);
            }
        }
    }
    if total == 0 {
        bail!("no conformance suite named {:?}", filter.unwrap_or("?"));
    }
    if failed > 0 {
        bail!("{failed} of {total} conformance run(s) failed");
    }
    println!("all {total} conformance runs passed");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "hvsim — gem5-style RISC-V simulator with the H extension\n\
         usage:\n  hvsim run   [--bench NAME] [--vm] [--scale N] [--config FILE] [--stats] [--echo] [--engine block|tick] [telemetry]\n  \
         hvsim sweep [--scale N] [--trace] [--out FILE]\n  \
         hvsim vmm   [--guests N] [--harts H] [--slice T] [--bench A,B] [--policy all|vmid|none] [--sched rr|slo|weighted:W,...|gang] [--slo BENCH=TICKS,...] [--engine block|tick] [telemetry]\n  \
         hvsim fleet [--nodes M] [--guests N] [--harts H] [--threads K] [--slice T] [--bench A,B] [--workload kv|echo] [--rate R] [--policy all|vmid|none] [--sched rr|slo|weighted:W,...|gang] [--slo BENCH=TICKS,...] [--engine block|tick] [--chaos SPEC] [--watchdog T] [--snap-every N] [--max-restarts R] [--strict] [--chaos-out F] [--requests-out F] [telemetry]\n  \
         hvsim timing [--bench NAME] [--vm] [--scale N] [--artifacts DIR]\n  \
         hvsim boot  [--bench NAME]\n  \
         hvsim fuzz  [--seed S] [--insts N] [--engine block|tick] [--selfcheck] [--prog FILE] [--prog-out FILE] [--trace-out FILE]\n  \
         hvsim conform [--engine block|tick|both] [--suite NAME]\n  hvsim list\n\
         telemetry: [--trace-out chrome.json] [--metrics-out metrics.json] [--events-out events.jsonl]"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "vmm" => cmd_vmm(&args),
        "fleet" => cmd_fleet(&args),
        "timing" => cmd_timing(&args),
        "boot" => cmd_boot(&args),
        "fuzz" => cmd_fuzz(&args),
        "conform" => cmd_conform(&args),
        "list" => {
            for b in sw::BENCHMARKS {
                println!("{b}");
            }
            Ok(())
        }
        _ => usage(),
    }
}
