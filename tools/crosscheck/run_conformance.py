#!/usr/bin/env python3
"""Run the H-extension conformance suites under the Python oracle.

The suites live in rust/src/sw/asm/conformance/*.s and are the same program
texts `hvsim conform` runs on the Rust tick and block engines; here they run
on the third, independent implementation. Each suite must power off through
the syscon device with the PASS code.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble
from emu import Machine

HERE = os.path.dirname(os.path.abspath(__file__))
SUITE_DIR = os.path.join(HERE, "..", "..", "rust", "src", "sw", "asm", "conformance")
RAM_BASE = 0x8000_0000
PASS_CODE = 0x5555


def run_suite(path, max_steps=2_000_000):
    with open(path) as f:
        src = f.read()
    m = Machine(ram_mb=8)
    ir, data, _syms = assemble(src, RAM_BASE)
    m.ir.update(ir)
    for addr, blob in data:
        off = addr - RAM_BASE
        m.ram[off:off + len(blob)] = blob
    m.pc = RAM_BASE
    reason = m.run(max_steps)
    return reason, m.poweroff


def main():
    names = sys.argv[1:] or sorted(
        f[:-2] for f in os.listdir(SUITE_DIR) if f.endswith(".s"))
    failed = []
    for name in names:
        reason, code = run_suite(os.path.join(SUITE_DIR, name + ".s"))
        ok = reason == "poweroff" and code == PASS_CODE
        shown = "none" if code is None else hex(code)
        print(f"{'PASS' if ok else 'FAIL'} {name} ({reason}, syscon={shown})")
        if not ok:
            failed.append(name)
    if failed:
        print(f"{len(failed)} conformance suite(s) failed: {', '.join(failed)}")
        sys.exit(1)
    print(f"all {len(names)} conformance suites passed under the Python oracle")


if __name__ == "__main__":
    main()
