#!/usr/bin/env python3
"""Parse the hvsim .s dialect into an IR stream with a faithful address
layout (li expansion sizes mirror rust/src/asm/encode.rs)."""
import re

class AsmError(Exception):
    pass

def strip_comment(raw):
    out, instr, i = "", False, 0
    while i < len(raw):
        c = raw[i]
        if c == '"':
            instr = not instr
        if c == '\\' and instr:
            out += raw[i:i+2]; i += 2; continue
        if c == '#' and not instr:
            break
        if c == '/' and not instr and i + 1 < len(raw) and raw[i+1] == '/':
            break
        out += c; i += 1
    return out

def split_ops(s):
    out, depth, cur, instr = [], 0, "", False
    for c in s:
        if c == '"':
            instr = not instr; cur += c
        elif c == '(' and not instr:
            depth += 1; cur += c
        elif c == ')' and not instr:
            depth -= 1; cur += c
        elif c == ',' and not instr and depth == 0:
            out.append(cur.strip()); cur = ""
        else:
            cur += c
    if cur.strip():
        out.append(cur.strip())
    return out

def parse_string(s):
    s = s.strip()
    assert s.startswith('"') and s.endswith('"')
    inner = s[1:-1]
    out = bytearray()
    it = iter(range(len(inner)))
    i = 0
    while i < len(inner):
        c = inner[i]
        if c == '\\':
            i += 1
            m = {'n': 10, 't': 9, 'r': 13, '0': 0, '\\': 92, '"': 34}
            out.append(m[inner[i]])
        else:
            out.extend(c.encode())
        i += 1
    return bytes(out)

# expression evaluator (mirrors expr.rs precedence)
def eval_expr(s, syms):
    tokens = re.findall(r"0[xX][0-9a-fA-F_]+|0b[01_]+|\d[\d_]*|'(?:\\.|[^'])'|<<|>>|[A-Za-z_.$][A-Za-z0-9_.$]*|[()+\-*/%|&^~]", s)
    pos = [0]
    def peek():
        return tokens[pos[0]] if pos[0] < len(tokens) else None
    def eat():
        t = tokens[pos[0]]; pos[0] += 1; return t
    def unary():
        t = peek()
        if t == '-':
            eat(); return (-unary()) & 0xFFFFFFFFFFFFFFFF
        if t == '~':
            eat(); return (~unary()) & 0xFFFFFFFFFFFFFFFF
        if t == '(':
            eat(); v = or_(); assert eat() == ')'; return v
        t = eat()
        if t.startswith("'"):
            body = t[1:-1]
            if body.startswith('\\'):
                return {'n': 10, 't': 9, '0': 0, '\\': 92, "'": 39}[body[1]]
            return ord(body)
        if re.fullmatch(r"0[xX][0-9a-fA-F_]+", t):
            return int(t.replace('_', ''), 16)
        if re.fullmatch(r"0b[01_]+", t):
            return int(t[2:].replace('_', ''), 2)
        if re.fullmatch(r"\d[\d_]*", t):
            return int(t.replace('_', ''))
        if t in syms:
            return syms[t] & 0xFFFFFFFFFFFFFFFF
        raise AsmError(f"unknown symbol {t!r} in {s!r}")
    def mul():
        v = unary()
        while peek() in ('*', '/', '%'):
            op = eat(); r = unary()
            if op == '*': v = (v * r) & 0xFFFFFFFFFFFFFFFF
            elif op == '/': v = v // r
            else: v = v % r
        return v
    def add():
        v = mul()
        while peek() in ('+', '-'):
            op = eat(); r = mul()
            v = (v + r if op == '+' else v - r) & 0xFFFFFFFFFFFFFFFF
        return v
    def shift():
        v = add()
        while peek() in ('<<', '>>'):
            op = eat(); r = add()
            v = (v << r if op == '<<' else v >> r) & 0xFFFFFFFFFFFFFFFF
        return v
    def and_():
        v = shift()
        while peek() == '&':
            eat(); v &= shift()
        return v
    def xor():
        v = and_()
        while peek() == '^':
            eat(); v ^= and_()
        return v
    def or_():
        v = xor()
        while peek() == '|':
            eat(); v |= xor()
        return v
    v = or_()
    if pos[0] != len(tokens):
        raise AsmError(f"trailing tokens in {s!r}")
    return v

def sext(v, bits):
    v &= (1 << bits) - 1
    if v & (1 << (bits - 1)):
        v -= 1 << bits
    return v

def li_len(imm):
    """Mirror encode.rs expand_li: number of 4-byte words."""
    if -2048 <= imm <= 2047:
        return 1
    if -(1 << 31) <= imm <= (1 << 31) - 1:
        hi = ((imm + 0x800) >> 12) & 0xFFFFF
        lo = imm - sext(hi << 12, 32)
        return 1 + (1 if lo != 0 else 0)
    lo12 = sext(imm, 12)
    hi = (imm - lo12) >> 12
    return li_len(hi) + 1 + (1 if lo12 != 0 else 0)

REGS = {f"x{i}": i for i in range(32)}
REGS.update({f"f{i}": i for i in range(32)})
ABI = ["zero","ra","sp","gp","tp","t0","t1","t2","s0","s1","a0","a1","a2","a3","a4",
       "a5","a6","a7","s2","s3","s4","s5","s6","s7","s8","s9","s10","s11","t3","t4","t5","t6"]
REGS.update({n: i for i, n in enumerate(ABI)})
REGS["fp"] = 8

def reg(s):
    s = s.strip()
    if s not in REGS:
        raise AsmError(f"bad register {s!r}")
    return REGS[s]

def mem_operand(s, syms):
    s = s.strip()
    open_i = s.find('(')
    if open_i < 0 or not s.endswith(')'):
        raise AsmError(f"bad mem operand {s!r}")
    off_str = s[:open_i].strip()
    off = sext(eval_expr(off_str, syms), 64) if off_str else 0
    return off, reg(s[open_i+1:-1])

def assemble(src, base):
    """Two-pass; returns (ir_by_addr dict, data bytes list [(addr, bytes)], symbols)."""
    # parse statements
    stmts = []
    for lineno, raw in enumerate(src.splitlines(), 1):
        rest = strip_comment(raw).strip()
        while True:
            m = re.match(r'^([A-Za-z0-9_.$]+):', rest)
            if not m:
                break
            stmts.append((lineno, 'label', m.group(1), []))
            rest = rest[m.end():].strip()
        if not rest:
            continue
        parts = rest.split(None, 1)
        head = parts[0]
        ops = split_ops(parts[1]) if len(parts) > 1 else []
        kind = 'dir' if head.startswith('.') else 'inst'
        stmts.append((lineno, kind, head.lower() if kind == 'inst' else head, ops))

    # resolve numeric labels into unique names (mirrors resolve_numeric_labels)
    counters, defs = {}, {}
    for i, (ln, kind, head, ops) in enumerate(stmts):
        if kind == 'label' and head.isdigit():
            k = counters.get(head, 0)
            uniq = f".L{head}.{k}"
            counters[head] = k + 1
            defs.setdefault(head, []).append(i)
            stmts[i] = (ln, 'label', uniq, ops)
    for i, (ln, kind, head, ops) in enumerate(stmts):
        if kind != 'inst':
            continue
        new_ops = []
        for op in ops:
            t = op.strip()
            m = re.fullmatch(r"(\d+)([fb])", t)
            if m:
                digit, d = m.groups()
                lst = defs.get(digit, [])
                if d == 'f':
                    ords = [j for j, s in enumerate(lst) if s > i]
                else:
                    ords = [j for j, s in enumerate(lst) if s < i]
                    ords = ords[-1:]  # nearest backward
                if not ords:
                    raise AsmError(f"line {ln}: unresolved numeric label {t}")
                k = ords[0]
                new_ops.append(f".L{digit}.{k}")
            else:
                new_ops.append(op)
        stmts[i] = (ln, kind, head, new_ops)

    # pass 1: layout
    syms = {}
    lc = base
    sizes = []
    for (ln, kind, head, ops) in stmts:
        if kind == 'label':
            syms[head] = lc
            sizes.append(0)
            continue
        if kind == 'dir':
            start = lc
            if head in ('.equ', '.set'):
                syms[ops[0]] = eval_expr(ops[1], syms)
            elif head == '.align':
                n = eval_expr(ops[0], syms)
                a = 1 << n
                lc = (lc + a - 1) & ~(a - 1)
            elif head == '.org':
                lc = eval_expr(ops[0], syms)
            elif head == '.byte':
                lc += len(ops)
            elif head == '.half':
                lc += 2 * len(ops)
            elif head == '.word':
                lc += 4 * len(ops)
            elif head in ('.dword', '.quad'):
                lc += 8 * len(ops)
            elif head in ('.space', '.zero'):
                lc += eval_expr(ops[0], syms)
            elif head in ('.ascii',):
                lc += len(parse_string(ops[0]))
            elif head in ('.asciz', '.string'):
                lc += len(parse_string(ops[0])) + 1
            elif head in ('.global', '.globl', '.text', '.data', '.section', '.option'):
                pass
            else:
                raise AsmError(f"line {ln}: unknown directive {head}")
            sizes.append(lc - start)
            continue
        # instruction sizing
        if head == 'li':
            v = sext(eval_expr(ops[1], syms), 64)
            n = 4 * li_len(v)
        elif head == 'la':
            n = 8
        else:
            n = 4
        sizes.append(n)
        lc += n

    # pass 2: emit IR + data
    ir = {}
    data = []
    lc = base
    for idx, (ln, kind, head, ops) in enumerate(stmts):
        if kind == 'label':
            continue
        if kind == 'dir':
            if head in ('.equ', '.set', '.global', '.globl', '.text', '.data', '.section', '.option'):
                pass
            elif head == '.align':
                n = eval_expr(ops[0], syms)
                a = 1 << n
                lc = (lc + a - 1) & ~(a - 1)
            elif head == '.org':
                lc = eval_expr(ops[0], syms)
            elif head in ('.byte', '.half', '.word', '.dword', '.quad'):
                size = {'.byte': 1, '.half': 2, '.word': 4, '.dword': 8, '.quad': 8}[head]
                blob = bytearray()
                for a in ops:
                    v = eval_expr(a, syms)
                    blob.extend((v & ((1 << (8*size)) - 1)).to_bytes(size, 'little'))
                data.append((lc, bytes(blob)))
                lc += len(blob)
            elif head in ('.space', '.zero'):
                n = eval_expr(ops[0], syms)
                data.append((lc, bytes(n)))
                lc += n
            elif head in ('.ascii',):
                b = parse_string(ops[0])
                data.append((lc, b)); lc += len(b)
            elif head in ('.asciz', '.string'):
                b = parse_string(ops[0]) + b'\0'
                data.append((lc, b)); lc += len(b)
            continue
        size = sizes[idx]
        ir[lc] = (ln, head, ops, size, syms)
        lc += size
    return ir, data, syms
