#!/usr/bin/env python3
"""Lockstep differential replay of an hvsim fuzz trace.

`hvsim fuzz --seed S --insts N --engine E --prog-out p.s --trace-out t.jsonl`
emits the generated program plus a JSONL trace of the Rust engine's run:

  {"t":"e","n":<retired>,"cause":<code>,"tgt":"M|HS|VS"}   trap events
  {"t":"s","n":<retired>,"pc":"0x..","h":"0x.."}           sync records
  {"t":"f","n":..,"pc":..,"prv":..,"virt":0|1,"poweroff":..,
   "regs":[..32 hex..],"csr":{..},"ram":"<sha256>"}        final state

This script re-executes the same program on the pure-Python oracle
(emu.py) and verifies, in order: the trap history, every sync record that
lands on an oracle statement boundary (the Rust tick engine records
machine-instruction boundaries; multi-word `li`/`la` expansions have no
oracle-visible interior), and the full final state — x0..x30 (x31 is the
trap handlers' sacrificial scratch), pc, privilege, V, the raw CSR file,
the poweroff code, and a SHA-256 over the data window of RAM.

Exit codes: 0 = lockstep clean, 2 = divergence, 1 = usage/internal error.

`--shrink` mode (needs `--hvsim CMD` to re-run the Rust side) greedily
deletes instruction lines from the program while the divergence persists
and writes a minimal reproducer.
"""
import argparse
import hashlib
import json
import os
import re
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble
from emu import Machine

RAM_BASE = 0x8000_0000
DIGEST_OFF = 0x40_0000
DIGEST_LEN = 0x40_0000
M64 = (1 << 64) - 1
FNV_OFFSET = 0xCBF2_9CE4_8422_2325
FNV_PRIME = 0x100_0000_01B3
# mstatus.UXL/SXL are read-only 64-bit indicators the Rust side hardwires
# to 2 and the oracle leaves at 0; everything else must match bit-exactly.
MSTATUS_XL_MASK = 0xF << 32


def state_hash(m):
    h = FNV_OFFSET
    for i in range(31):
        for b in m.regs[i].to_bytes(8, "little"):
            h = ((h ^ b) * FNV_PRIME) & M64
    h = ((h ^ m.prv) * FNV_PRIME) & M64
    h = ((h ^ (1 if m.virt else 0)) * FNV_PRIME) & M64
    return h


def load_trace(path):
    syncs, traps, final = [], [], None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec["t"] == "s":
                syncs.append((rec["n"], int(rec["pc"], 16), int(rec["h"], 16)))
            elif rec["t"] == "e":
                traps.append((rec["n"], rec["cause"], rec["tgt"]))
            elif rec["t"] == "f":
                final = rec
    if final is None:
        raise SystemExit("trace has no final ('f') record — truncated run?")
    return syncs, traps, final


def replay(src, sync_ats, max_steps):
    """Run the oracle; returns (machine, boundaries{cum: pc}, hashes{cum: h},
    traps[(cum, cause, tgt)], cum)."""
    m = Machine(ram_mb=8)
    ir, data, _syms = assemble(src, RAM_BASE)
    m.ir.update(ir)
    for addr, blob in data:
        off = addr - RAM_BASE
        m.ram[off:off + len(blob)] = blob
    m.pc = RAM_BASE

    cum = 0
    boundaries, hashes, traps = {}, {}, []
    m.trap_hook = lambda code, target, t: traps.append((cum, code, target))
    for _ in range(max_steps):
        if m.poweroff is not None:
            break
        size = m.step()
        if size is None:
            continue
        cum += size // 4
        boundaries[cum] = m.pc
        if cum in sync_ats:
            hashes[cum] = state_hash(m)
    return m, boundaries, hashes, traps, cum


def compare(src, trace_path, max_steps, verbose=True):
    """Returns a list of divergence strings (empty = lockstep clean)."""
    syncs, traps, final = load_trace(trace_path)
    sync_ats = {n for n, _, _ in syncs}
    try:
        m, boundaries, hashes, py_traps, cum = replay(src, sync_ats, max_steps)
    except RuntimeError as e:
        return [f"oracle replay aborted: {e}"]

    out = []

    # Trap history first: a control-flow split shows up here with the
    # retired-instruction index of the first disagreement.
    for i, (a, b) in enumerate(zip(traps, py_traps)):
        if a != b:
            out.append(
                f"trap[{i}] diverges: rust (at={a[0]}, cause={a[1]}, tgt={a[2]})"
                f" vs oracle (at={b[0]}, cause={b[1]}, tgt={b[2]})")
            return out
    if len(traps) != len(py_traps):
        out.append(f"trap count diverges: rust {len(traps)} vs oracle {len(py_traps)}")
        return out

    # Sync records at statement boundaries. Records inside a multi-word
    # li/la expansion have no oracle counterpart and are skipped.
    matched = 0
    for n, pc, h in syncs:
        if n not in boundaries:
            continue
        matched += 1
        if boundaries[n] != pc:
            out.append(
                f"pc diverges at retired={n}: rust {pc:#x} vs oracle {boundaries[n]:#x}")
            return out
        if hashes.get(n) != h:
            out.append(
                f"state hash diverges at retired={n} (pc={pc:#x}):"
                f" rust {h:#x} vs oracle {hashes.get(n, 0):#x}")
            return out
    if syncs and matched == 0:
        out.append("no sync record landed on an oracle boundary — timeline drift")
        return out

    # Final architectural state.
    if final["n"] != cum:
        out.append(f"retired count diverges: rust {final['n']} vs oracle {cum}")
    f_regs = [int(v, 16) for v in final["regs"]]
    for i in range(31):
        if f_regs[i] != m.regs[i]:
            out.append(f"final x{i} diverges: rust {f_regs[i]:#x} vs oracle {m.regs[i]:#x}")
    if int(final["pc"], 16) != m.pc:
        out.append(f"final pc diverges: rust {int(final['pc'], 16):#x} vs oracle {m.pc:#x}")
    if final["prv"] != m.prv:
        out.append(f"final prv diverges: rust {final['prv']} vs oracle {m.prv}")
    if final["virt"] != (1 if m.virt else 0):
        out.append(f"final V diverges: rust {final['virt']} vs oracle {int(m.virt)}")
    rust_off = final["poweroff"]
    if rust_off != m.poweroff:
        out.append(f"poweroff diverges: rust {rust_off} vs oracle {m.poweroff}")
    for name, sval in final["csr"].items():
        rv, pv = int(sval, 16), m.csr[name]
        if name == "mstatus":
            rv &= ~MSTATUS_XL_MASK
            pv &= ~MSTATUS_XL_MASK
        if rv != pv:
            out.append(f"final {name} diverges: rust {rv:#x} vs oracle {pv:#x}")
    sha = hashlib.sha256(m.ram[DIGEST_OFF:DIGEST_OFF + DIGEST_LEN]).hexdigest()
    if final["ram"] != sha:
        out.append(f"RAM digest diverges: rust {final['ram']} vs oracle {sha}")

    if verbose and not out:
        print(f"lockstep clean: {cum} retired insts, {len(py_traps)} traps, "
              f"{matched} sync records matched")
    return out


# ---------------------------------------------------------------- shrink

INST_RE = re.compile(r"^\s+[a-z]")


def still_diverges(lines, hvsim, engine, max_steps, workdir):
    src = "\n".join(lines) + "\n"
    prog = os.path.join(workdir, "cand.s")
    trace = os.path.join(workdir, "cand.jsonl")
    with open(prog, "w") as f:
        f.write(src)
    r = subprocess.run(
        hvsim + ["fuzz", "--prog", prog, "--engine", engine, "--trace-out", trace],
        capture_output=True)
    if r.returncode != 0 or not os.path.exists(trace):
        return False  # candidate no longer even runs — reject it
    try:
        return bool(compare(src, trace, max_steps, verbose=False))
    except (SystemExit, Exception):
        return False


def shrink(src, hvsim, engine, max_steps, out_path):
    lines = src.splitlines()
    with tempfile.TemporaryDirectory() as workdir:
        if not still_diverges(lines, hvsim, engine, max_steps, workdir):
            print("shrink: baseline does not diverge — nothing to do", file=sys.stderr)
            return False
        # Greedy delta-debugging over instruction lines (labels and
        # directives stay; removing them would orphan references).
        chunk = max(1, len(lines) // 2)
        while chunk >= 1:
            i = 0
            while i < len(lines):
                cand_idx = [
                    j for j in range(i, min(i + chunk, len(lines)))
                    if INST_RE.match(lines[j])
                ]
                if cand_idx:
                    cand = [l for j, l in enumerate(lines) if j not in set(cand_idx)]
                    if still_diverges(cand, hvsim, engine, max_steps, workdir):
                        lines = cand
                        continue  # retry same window against shifted lines
                i += chunk
            chunk //= 2
    with open(out_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    kept = sum(1 for l in lines if INST_RE.match(l))
    print(f"shrink: wrote {out_path} ({kept} instruction lines)")
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--prog", required=True, help="generated .s program")
    ap.add_argument("--trace", required=True, help="JSONL trace from hvsim fuzz")
    ap.add_argument("--max-steps", type=int, default=5_000_000)
    ap.add_argument("--shrink", action="store_true",
                    help="on divergence, shrink --prog to a minimal reproducer")
    ap.add_argument("--hvsim", default="",
                    help="hvsim command for --shrink, e.g. 'target/release/hvsim'")
    ap.add_argument("--engine", default="block", choices=["tick", "block"],
                    help="engine to re-run during --shrink")
    ap.add_argument("--shrink-out", default="repro_min.s")
    args = ap.parse_args()

    with open(args.prog) as f:
        src = f.read()
    problems = compare(src, args.trace, args.max_steps)
    if not problems:
        return
    print("LOCKSTEP DIVERGENCE:", file=sys.stderr)
    for p in problems:
        print(f"  {p}", file=sys.stderr)
    if args.shrink:
        if not args.hvsim:
            print("--shrink needs --hvsim CMD", file=sys.stderr)
            sys.exit(1)
        shrink(src, args.hvsim.split(), args.engine, args.max_steps, args.shrink_out)
    sys.exit(2)


if __name__ == "__main__":
    main()
