#!/usr/bin/env python3
"""Boot the hvsim embedded software stack (firmware/hypervisor/kernel/
benchmarks) on the Python cross-checker, native and guest, all nine
benchmarks. Benchmark input sizes are scaled down so pure-Python emulation
finishes quickly; every logic path (paging, syscalls, SBI relays, G-stage
demand paging, shutdown) is exercised identically."""
import math, os, re, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble
from emu import Machine

BASE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "src", "sw", "asm") + os.sep
FW_BASE = 0x8000_0000
HV_BASE = 0x8010_0000
KERNEL_BASE = 0x8020_0000
GUEST_OFF = 0x0200_0000

SHRINK = {
    "QS_N_BASE": 256, "BC_N_BASE": 512, "CRC_N_BASE": 512, "SHA_N_BASE": 512,
    "SS_N_BASE": 2048, "BM_N_BASE": 256,
}

def read(p):
    return open(BASE + p).read()

def shrink(src):
    for k, v in SHRINK.items():
        src = re.sub(rf"\.equ\s+{k},\s*\d+", f".equ {k}, {v}", src)
    src = src.replace("li   s4, 8\n", "li   s4, 1\n")  # dijkstra rounds
    return src

def fft_rom(n=1024):
    out = [".align 3", "tw_cos:"]
    q = 1 << 14
    for k in range(n // 2):
        ang = -2.0 * math.pi * k / n
        out.append(f".word {int(round(math.cos(ang) * q)) & 0xFFFFFFFF}")
    out.append("tw_sin:")
    for k in range(n // 2):
        ang = -2.0 * math.pi * k / n
        out.append(f".word {int(round(math.sin(ang) * q)) & 0xFFFFFFFF}")
    return "\n".join(out) + "\n"

def kernel_src(bench, scale=1):
    extra = fft_rom() if bench == "fft" else ""
    return (f".equ SCALE, {scale}\n" + read("kernel.s") + "\n" + read("prelude.s") + "\n"
            + shrink(read(f"bench/{bench}.s")) + "\n" + extra + "\n.align 12\nucode_end:\n")

def load(m, src, base):
    ir, data, syms = assemble(src, base)
    m.ir.update(ir)
    for addr, blob in data:
        off = addr - 0x8000_0000
        m.ram[off:off + len(blob)] = blob
    return syms

def run_native(bench, max_steps=30_000_000):
    m = Machine()
    load(m, read("firmware.s"), FW_BASE)
    load(m, kernel_src(bench), KERNEL_BASE)
    m.pc = FW_BASE
    m.regs[10], m.regs[11], m.regs[12] = 0, KERNEL_BASE, 0
    r = m.run(max_steps)
    return m, r

def run_guest(bench, max_steps=40_000_000):
    m = Machine()
    load(m, read("firmware.s"), FW_BASE)
    load(m, ".equ GUEST_VMID, 1\n" + read("hypervisor.s"), HV_BASE)
    load(m, kernel_src(bench), KERNEL_BASE + GUEST_OFF)
    m.pc = FW_BASE
    m.regs[10], m.regs[11], m.regs[12] = 0, HV_BASE, 1
    r = m.run(max_steps)
    return m, r

def console(m):
    return m.uart.decode(errors="replace")

def check_console(name, out, vm):
    lines = out.splitlines()
    assert lines, f"{name}: empty console"
    assert lines[0] == "mini-os: up", f"{name}: bad first line {lines[0]!r}"
    cks = [l for l in lines if len(l) == 16 and all(c in "0123456789abcdef" for c in l)]
    assert len(cks) == 1, f"{name}: checksum lines {cks!r} in {out!r}"
    if vm:
        assert any(l.startswith("xvisor: pf/ecall/irq/virt ") for l in lines), \
            f"{name}: missing xvisor summary: {out!r}"
        assert lines[-2] == "mini-os: benchmark done", f"{name}: {lines!r}"
    else:
        assert lines[-1] == "mini-os: benchmark done", f"{name}: {lines!r}"
    return cks[0]

BENCHES = ["qsort", "bitcount", "crc32", "sha", "stringsearch", "dijkstra",
           "basicmath", "fft", "susan"]

def main():
    only = sys.argv[1:] or BENCHES
    for bench in only:
        nm, nr = run_native(bench)
        nout = console(nm)
        assert nr == 'poweroff' and nm.poweroff == 0x5555, \
            f"native {bench}: {nr} poweroff={nm.poweroff} console={nout!r} pc={nm.pc:#x} " \
            f"prv={nm.prv} virt={nm.virt} scause={nm.csr['scause']:#x} stval={nm.csr['stval']:#x} " \
            f"mcause={nm.csr['mcause']:#x} mtval={nm.csr['mtval']:#x}"
        nck = check_console(f"native {bench}", nout, vm=False)
        s_excs = sum(v for (c, t), v in nm.exc_counts.items() if t == 'HS')
        m_excs = sum(v for (c, t), v in nm.exc_counts.items() if t == 'M')
        assert s_excs > 0 and m_excs > 0, f"native {bench}: exc {nm.exc_counts}"

        gm, gr = run_guest(bench)
        gout = console(gm)
        assert gr == 'poweroff' and gm.poweroff == 0x5555, \
            f"guest {bench}: {gr} poweroff={gm.poweroff} console={gout!r} pc={gm.pc:#x} " \
            f"prv={gm.prv} virt={gm.virt} scause={gm.csr['scause']:#x} stval={gm.csr['stval']:#x} " \
            f"vscause={gm.csr['vscause']:#x} mcause={gm.csr['mcause']:#x} htval={gm.csr['htval']:#x}"
        gck = check_console(f"guest {bench}", gout, vm=True)
        assert gout.startswith(nout), f"{bench}: guest console is not native-prefixed:\n{nout!r}\nvs\n{gout!r}"
        assert gck == nck, f"{bench}: checksum mismatch {nck} vs {gck}"
        vs_excs = sum(v for (c, t), v in gm.exc_counts.items() if t == 'VS')
        hs_excs = sum(v for (c, t), v in gm.exc_counts.items() if t == 'HS')
        gpf = sum(v for (c, t), v in gm.exc_counts.items() if c in (20, 21, 23))
        assert vs_excs > 0 and hs_excs > 0 and gpf > 0, f"guest {bench}: exc {gm.exc_counts}"
        assert vs_excs == s_excs, f"{bench}: VS-guest {vs_excs} != S-native {s_excs} (§4.3)"
        assert gm.insts > nm.insts, f"{bench}: guest insts {gm.insts} <= native {nm.insts}"
        print(f"{bench:<13} ok  cksum={nck}  native(insts={nm.insts} S={s_excs} M={m_excs})  "
              f"guest(insts={gm.insts} VS={vs_excs} HS={hs_excs} gpf={gpf})")
    print("ALL STACK CHECKS PASSED")

if __name__ == "__main__":
    main()

def oom_check():
    """machine_ops::out_of_guest_memory_fails_cleanly analog."""
    import types
    kernel_extra = """
bench_main:
    li   s0, HEAP0
    li   s1, 2000
1:  sb   zero, 0(s0)
    li   t0, 0x1000
    add  s0, s0, t0
    addi s1, s1, -1
    bnez s1, 1b
    li   a0, 0
    call u_exit
"""
    src = (".equ SCALE, 1\n" + read("kernel.s") + "\n" + read("prelude.s") + "\n"
           + kernel_extra + "\n.align 12\nucode_end:\n")
    m = Machine()
    load(m, read("firmware.s"), FW_BASE)
    load(m, src, KERNEL_BASE)
    m.pc = FW_BASE
    m.regs[10], m.regs[11], m.regs[12] = 0, KERNEL_BASE, 0
    r = m.run(30_000_000)
    out = console(m)
    assert r == 'poweroff' and m.poweroff == 0x3333, f"oom: {r} {m.poweroff} {out!r}"
    assert "K! " in out, f"oom console: {out!r}"
    print(f"oom-failstop  ok  (console tail: {out.splitlines()[-1]!r})")
