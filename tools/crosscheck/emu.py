#!/usr/bin/env python3
"""RV64 M/HS/VS/U emulator over the asm2ir IR, mirroring hvsim's Rust
semantics (cpu/trap.rs, cpu/csr.rs redirection, mmu/walker.rs two-stage
Sv39/Sv39x4, and the full hypervisor-instruction surface: HLV/HSV/HLVX,
HFENCE legality, mstatus.GVA/MPV + htval/htinst/mtinst trap writes).
Used to cross-check the embedded software stack offline and as the
differential-fuzzing oracle (tools/crosscheck/fuzz_lockstep.py)."""
import os, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble, sext, eval_expr, reg, mem_operand

M64 = (1 << 64) - 1
RAM_BASE = 0x8000_0000
UART = 0x1000_0000
SYSCON = 0x10_0000
# Paravirtual MMIO apertures (dev/virtio.rs). The emulator models them as
# passive register files (magic/version readable, all registers writable):
# enough for the firmware's DMA_OFF programming on the guest boot path.
# The request-serving workloads (echo/kvstore) need the live service()
# machinery and are cross-checked natively in Rust instead.
VIRTIO_QUEUE_BASE = 0x1000_1000
VIRTIO_BLK_BASE = 0x1000_2000
VIRTIO_SIZE = 0x1000
VIRTIO_MAGIC = 0x7472_6976

# mstatus bits
SIE, MIE, SPIE, MPIE, SPP = 1 << 1, 1 << 3, 1 << 5, 1 << 7, 1 << 8
MPP_SHIFT = 11
FS_MASK = 3 << 13
MPRV = 1 << 17
SUM_BIT, MXR = 1 << 18, 1 << 19
TVM, TW, TSR = 1 << 20, 1 << 21, 1 << 22
MPV, GVA = 1 << 39, 1 << 38
SD = 1 << 63
# hstatus bits
H_GVA, SPV, SPVP, HU = 1 << 6, 1 << 7, 1 << 8, 1 << 9
VGEIN_MASK = 0x3F << 12
VTVM, VTW, VTSR = 1 << 20, 1 << 21, 1 << 22
# interrupt bits (isa/csr.rs irq)
SSIP, VSSIP, MSIP = 1 << 1, 1 << 2, 1 << 3
STIP, VSTIP, MTIP = 1 << 5, 1 << 6, 1 << 7
SEIP, VSEIP, MEIP = 1 << 9, 1 << 10, 1 << 11
SGEIP = 1 << 12
VS_MASK_I = VSSIP | VSTIP | VSEIP
S_MASK_I = SSIP | STIP | SEIP
M_MASK_I = MSIP | MTIP | MEIP
HS_MASK_I = VS_MASK_I | SGEIP

TINST_PSEUDO_PTE_READ = 0x2020

# write masks (cpu/csr.rs)
SSTATUS_WMASK = SIE | SPIE | SPP | FS_MASK | SUM_BIT | MXR
MSTATUS_WMASK = (SIE | MIE | SPIE | MPIE | SPP | (3 << MPP_SHIFT) | FS_MASK
                 | MPRV | SUM_BIT | MXR | TVM | TW | TSR | MPV | GVA)
HSTATUS_WMASK = H_GVA | SPV | SPVP | HU | VGEIN_MASK | VTVM | VTW | VTSR
HEDELEG_WMASK = 0x1FF | (1 << 12) | (1 << 13) | (1 << 15)
MEDELEG_WMASK = HEDELEG_WMASK | (1 << 9) | (1 << 10) | (0xF << 20)
HGEIE_MASK = 0x1FE

# CSR name -> address (isa/csr.rs); used for privilege checks and for
# reconstructing raw instruction encodings for tval/tinst.
CSR_ADDR = {
    'fflags': 0x001, 'frm': 0x002, 'fcsr': 0x003,
    'cycle': 0xC00, 'time': 0xC01, 'instret': 0xC02,
    'sstatus': 0x100, 'sie': 0x104, 'stvec': 0x105, 'scounteren': 0x106,
    'senvcfg': 0x10A, 'sscratch': 0x140, 'sepc': 0x141, 'scause': 0x142,
    'stval': 0x143, 'sip': 0x144, 'satp': 0x180,
    'hstatus': 0x600, 'hedeleg': 0x602, 'hideleg': 0x603, 'hie': 0x604,
    'htimedelta': 0x605, 'hcounteren': 0x606, 'hgeie': 0x607,
    'henvcfg': 0x60A, 'htval': 0x643, 'hip': 0x644, 'hvip': 0x645,
    'htinst': 0x64A, 'hgatp': 0x680, 'hgeip': 0xE12,
    'vsstatus': 0x200, 'vsie': 0x204, 'vstvec': 0x205, 'vsscratch': 0x240,
    'vsepc': 0x241, 'vscause': 0x242, 'vstval': 0x243, 'vsip': 0x244,
    'vsatp': 0x280,
    'mvendorid': 0xF11, 'marchid': 0xF12, 'mimpid': 0xF13, 'mhartid': 0xF14,
    'mstatus': 0x300, 'misa': 0x301, 'medeleg': 0x302, 'mideleg': 0x303,
    'mie': 0x304, 'mtvec': 0x305, 'mcounteren': 0x306, 'menvcfg': 0x30A,
    'mscratch': 0x340, 'mepc': 0x341, 'mcause': 0x342, 'mtval': 0x343,
    'mip': 0x344, 'mtinst': 0x34A, 'mtval2': 0x34B,
    'mcycle': 0xB00, 'minstret': 0xB02,
}
H_CSRS = {'hstatus', 'hedeleg', 'hideleg', 'hie', 'htimedelta', 'hcounteren',
          'hgeie', 'henvcfg', 'htval', 'hip', 'hvip', 'htinst', 'hgatp', 'hgeip'}
VS_CSRS = {'vsstatus', 'vsie', 'vstvec', 'vsscratch', 'vsepc', 'vscause',
           'vstval', 'vsip', 'vsatp'}

# encodings (asm/encode.rs) for raw-instruction tval/tinst reconstruction
LOAD_F3 = {'lb': 0, 'lh': 1, 'lw': 2, 'ld': 3, 'lbu': 4, 'lhu': 5, 'lwu': 6}
STORE_F3 = {'sb': 0, 'sh': 1, 'sw': 2, 'sd': 3}
HLV_CODE = {'hlv.b': (0x30, 0), 'hlv.bu': (0x30, 1), 'hlv.h': (0x32, 0),
            'hlv.hu': (0x32, 1), 'hlvx.hu': (0x32, 3), 'hlv.w': (0x34, 0),
            'hlv.wu': (0x34, 1), 'hlvx.wu': (0x34, 3), 'hlv.d': (0x36, 0)}
HSV_CODE = {'hsv.b': 0x31, 'hsv.h': 0x33, 'hsv.w': 0x35, 'hsv.d': 0x37}
# head -> (size, signed, hlvx)
HLV_META = {'hlv.b': (1, True, False), 'hlv.bu': (1, False, False),
            'hlv.h': (2, True, False), 'hlv.hu': (2, False, False),
            'hlvx.hu': (2, False, True), 'hlv.w': (4, True, False),
            'hlv.wu': (4, False, False), 'hlvx.wu': (4, False, True),
            'hlv.d': (8, False, False)}
HSV_SIZE = {'hsv.b': 1, 'hsv.h': 2, 'hsv.w': 4, 'hsv.d': 8}
FENCE_F7 = {'sfence.vma': 0x09, 'hfence.vvma': 0x11, 'hfence.gvma': 0x31}
RAW_MRET, RAW_SRET, RAW_WFI = 0x3020_0073, 0x1020_0073, 0x1050_0073


class Trap(Exception):
    def __init__(self, cause, tval, gpa=0, gva=False, tinst=0):
        self.cause, self.tval, self.gpa, self.gva = cause, tval, gpa, gva
        self.tinst = tinst


class Machine:
    def __init__(self, ram_mb=64):
        self.ram = bytearray(ram_mb << 20)
        self.regs = [0] * 32
        self.pc = 0
        self.prv = 3
        self.virt = False
        self.csr = {n: 0 for n in (
            'mstatus vsstatus medeleg mideleg hedeleg hideleg mie mip mtvec stvec vstvec '
            'mscratch sscratch vsscratch mepc sepc vsepc mcause scause vscause mtval stval '
            'vstval mtval2 htval mtinst htinst satp vsatp hgatp hstatus htimedelta '
            'mcounteren scounteren hcounteren menvcfg senvcfg henvcfg hgeie hgeip'
        ).split()}
        self.uart = bytearray()
        self.virtio = {VIRTIO_QUEUE_BASE: bytearray(VIRTIO_SIZE),
                       VIRTIO_BLK_BASE: bytearray(VIRTIO_SIZE)}
        for regs in self.virtio.values():
            regs[0:4] = VIRTIO_MAGIC.to_bytes(4, 'little')
            regs[4:8] = (2).to_bytes(4, 'little')
        self.poweroff = None
        self.ir = {}
        self.insts = 0
        self.exc_counts = {}
        # Optional hook: called as trap_hook(cause, target, trap) on every
        # exception entry (the fuzzer records trap events through this).
        self.trap_hook = None

    # ---------------- physical memory ----------------
    def pread(self, pa, size):
        if RAM_BASE <= pa and pa + size <= RAM_BASE + len(self.ram):
            off = pa - RAM_BASE
            return int.from_bytes(self.ram[off:off + size], 'little')
        if pa == SYSCON:
            return 0
        for base, regs in self.virtio.items():
            if base <= pa and pa + size <= base + VIRTIO_SIZE:
                return int.from_bytes(regs[pa - base:pa - base + size], 'little')
        raise Trap(5, pa)  # load access fault; callers rewrite tval to va

    def pwrite(self, pa, size, val):
        if RAM_BASE <= pa and pa + size <= RAM_BASE + len(self.ram):
            off = pa - RAM_BASE
            self.ram[off:off + size] = (val & ((1 << (8 * size)) - 1)).to_bytes(size, 'little')
            return
        if UART <= pa < UART + 0x100:
            if pa == UART:
                self.uart.append(val & 0xFF)
            return
        if pa == SYSCON:
            self.poweroff = val & 0xFFFFFFFF
            return
        for base, regs in self.virtio.items():
            if base <= pa and pa + size <= base + VIRTIO_SIZE:
                regs[pa - base:pa - base + size] = \
                    (val & ((1 << (8 * size)) - 1)).to_bytes(size, 'little')
                return
        raise Trap(7, pa)

    # ---------------- translation (walker.rs) ----------------
    def walk_g(self, va, gpa, access, implicit, cause_access=None, hlvx=False, tinst=0):
        # Guest-page-fault cause follows the ORIGINAL access (walker.rs
        # stage2_cause uses ctx.access even for implicit PTE reads).
        cause = {'x': 20, 'r': 21, 'w': 23}[cause_access or access]
        ti = TINST_PSEUDO_PTE_READ if implicit else tinst
        if gpa >> 41:
            raise Trap(cause, va, gpa, True, ti)
        a = (self.csr['hgatp'] & ((1 << 44) - 1)) << 12
        level = 2
        while True:
            idx = (gpa >> 30) & 0x7FF if level == 2 else (gpa >> (12 + 9 * level)) & 0x1FF
            raw = self.pread(a + idx * 8, 8)
            perms = raw & 0xFF
            ppn = (raw >> 10) & ((1 << 44) - 1)
            V, R, W, X, U, A, D = (perms & 1, perms & 2, perms & 4, perms & 8,
                                   perms & 16, perms & 64, perms & 128)
            if not V or (not R and W):
                raise Trap(cause, va, gpa, True, ti)
            if R or X:
                span = (1 << (9 * level)) - 1
                if ppn & span:
                    raise Trap(cause, va, gpa, True, ti)
                if implicit and (not U or not R or not A):
                    raise Trap(cause, va, gpa, True, ti)
                # final-access perms checked here for the non-implicit case
                if not implicit:
                    if not U:
                        raise Trap(cause, va, gpa, True, ti)
                    # G-stage MXR: only mstatus.MXR applies here; HLVX wants
                    # X at this stage regardless (tlb.rs check_permissions).
                    mxr2 = bool(self.csr['mstatus'] & MXR)
                    if access == 'x':
                        ok = X
                    elif access == 'r':
                        ok = X if hlvx else (R or (mxr2 and X))
                    else:
                        ok = W
                    if not ok:
                        raise Trap(cause, va, gpa, True, ti)
                    if not A or (access == 'w' and not D):
                        raise Trap(cause, va, gpa, True, ti)
                page = (ppn & ~span) | ((gpa >> 12) & span)
                return (page << 12) | (gpa & 0xFFF)
            if perms & (16 | 64 | 128):
                raise Trap(cause, va, gpa, True, ti)
            level -= 1
            if level < 0:
                raise Trap(cause, va, gpa, True, ti)
            a = ppn << 12

    def translate(self, va, access, prv=None, virt=None, hlvx=False, forced=False, tinst=0):
        prv = self.prv if prv is None else prv
        virt = self.virt if virt is None else virt
        cause1 = {'x': 12, 'r': 13, 'w': 15}[access]
        if virt:
            s1_atp = self.csr['vsatp']
            s1_on = (s1_atp >> 60) == 8
        elif prv == 3:
            s1_on, s1_atp = False, 0
        else:
            s1_atp = self.csr['satp']
            s1_on = (s1_atp >> 60) == 8
        s2_on = virt and (self.csr['hgatp'] >> 60) == 8
        if not s1_on and not s2_on:
            return va
        if s1_on:
            if sext(va, 39) & M64 != va:
                raise Trap(cause1, va, 0, virt)
            a = (s1_atp & ((1 << 44) - 1)) << 12
            level = 2
            while True:
                idx = (va >> (12 + 9 * level)) & 0x1FF
                pte_addr = a + idx * 8
                pte_pa = (self.walk_g(va, pte_addr, 'r', True, cause_access=access)
                          if s2_on else pte_addr)
                raw = self.pread(pte_pa, 8)
                perms = raw & 0xFF
                ppn = (raw >> 10) & ((1 << 44) - 1)
                V, R, W, X, U, A, D = (perms & 1, perms & 2, perms & 4, perms & 8,
                                       perms & 16, perms & 64, perms & 128)
                if not V or (not R and W):
                    raise Trap(cause1, va, 0, virt)
                if R or X:
                    span = (1 << (9 * level)) - 1
                    if ppn & span:
                        raise Trap(cause1, va, 0, virt)
                    # stage-1 permission check (tlb.rs check_permissions).
                    # HLV/HSV act "as if SUM were set" (walker.rs forced_virt)
                    # and the stage-1 MXR disjunction is vsstatus.MXR ||
                    # mstatus.MXR when V=1.
                    st = self.csr['vsstatus'] if virt else self.csr['mstatus']
                    sum_ok = bool(st & SUM_BIT) or forced
                    if virt:
                        mxr = bool((self.csr['vsstatus'] | self.csr['mstatus']) & MXR)
                    else:
                        mxr = bool(self.csr['mstatus'] & MXR)
                    user = prv == 0
                    if user and not U:
                        raise Trap(cause1, va, 0, virt)
                    if not user and U and (not sum_ok or access == 'x'):
                        raise Trap(cause1, va, 0, virt)
                    if access == 'x':
                        ok = X
                    elif access == 'r':
                        ok = X if hlvx else (R or (mxr and X))
                    else:
                        ok = W
                    if not ok:
                        raise Trap(cause1, va, 0, virt)
                    if not A or (access == 'w' and not D):
                        raise Trap(cause1, va, 0, virt)
                    page = (ppn & ~span) | ((va >> 12) & span)
                    gpa = (page << 12) | (va & 0xFFF)
                    break
                if perms & (16 | 64 | 128):
                    raise Trap(cause1, va, 0, virt)
                level -= 1
                if level < 0:
                    raise Trap(cause1, va, 0, virt)
                a = ppn << 12
        else:
            gpa = va
        if s2_on:
            return self.walk_g(va, gpa, access, False, hlvx=hlvx, tinst=tinst)
        return gpa

    # ---------------- CSR access (csr.rs) ----------------
    REDIR = {'sstatus': 'vsstatus', 'stvec': 'vstvec', 'sscratch': 'vsscratch',
             'sepc': 'vsepc', 'scause': 'vscause', 'stval': 'vstval',
             'satp': 'vsatp', 'sie': 'vsie', 'sip': 'vsip'}
    SSTATUS_MASK = SSTATUS_WMASK  # compat alias

    def _mip_read(self):
        v = self.csr['mip']
        if self.csr['hgeip'] & self.csr['hgeie']:
            v |= SGEIP
        return v

    def _status_view(self, v):
        out = (v & SSTATUS_WMASK) | (2 << 32)  # UXL=64
        if v & FS_MASK == FS_MASK:
            out |= SD
        return out

    def csr_check(self, name, raw, write):
        """Mirror csr.rs check_access: raises Trap(2) / Trap(22); returns
        the effective (redirected) CSR name."""
        addr = CSR_ADDR.get(name)
        if addr is None:
            raise RuntimeError(f"emulator: unknown CSR {name!r}")
        if write and (addr >> 10) & 3 == 3:
            raise Trap(2, raw)  # read-only CSR
        if self.virt and (name in H_CSRS or name in VS_CSRS):
            raise Trap(22, raw)
        eff = 3 if self.prv == 3 else (2 if (self.prv == 1 and not self.virt)
                                       else (1 if self.prv == 1 else 0))
        min_priv = (addr >> 8) & 3
        if eff < min_priv:
            if self.virt and min_priv <= 2:
                raise Trap(22, raw)
            raise Trap(2, raw)
        if self.virt and name in self.REDIR:
            return self.REDIR[name]
        return name

    def csr_read(self, name):
        if self.virt and name in self.REDIR:
            name = self.REDIR[name]
        c = self.csr
        if name == 'sstatus':
            return self._status_view(c['mstatus'])
        if name == 'vsstatus':
            return self._status_view(c['vsstatus'])
        if name == 'mstatus':
            v = c['mstatus']
            return v | SD if v & FS_MASK == FS_MASK else v
        if name == 'sie':
            return c['mie'] & S_MASK_I
        if name == 'sip':
            return self._mip_read() & S_MASK_I
        if name == 'hie':
            return c['mie'] & HS_MASK_I
        if name == 'hip':
            return self._mip_read() & HS_MASK_I
        if name == 'hvip':
            return c['mip'] & VS_MASK_I
        if name == 'vsie':
            return (c['mie'] & c['hideleg'] & VS_MASK_I) >> 1
        if name == 'vsip':
            return (c['mip'] & c['hideleg'] & VS_MASK_I) >> 1
        if name == 'mip':
            return self._mip_read()
        if name == 'mideleg':
            return c['mideleg'] | VS_MASK_I | SGEIP
        if name == 'misa':
            return (2 << 62) | 1 | (1 << 5) | (1 << 7) | (1 << 8) | (1 << 12) | (1 << 18) | (1 << 20)
        if name == 'mvendorid':
            return 0
        if name == 'marchid':
            return 0x68767369
        if name == 'mimpid':
            return 1
        if name == 'mhartid':
            return 0
        if name in ('cycle', 'time', 'instret', 'mcycle', 'minstret'):
            raise RuntimeError("emulator: counter CSRs are not modeled")
        return c[name]

    def csr_write(self, name, val):
        if self.virt and name in self.REDIR:
            name = self.REDIR[name]
        c = self.csr
        val &= M64
        if name == 'sstatus':
            c['mstatus'] = (c['mstatus'] & ~SSTATUS_WMASK) | (val & SSTATUS_WMASK)
        elif name == 'vsstatus':
            c['vsstatus'] = (c['vsstatus'] & ~SSTATUS_WMASK) | (val & SSTATUS_WMASK)
        elif name == 'mstatus':
            v = (c['mstatus'] & ~MSTATUS_WMASK) | (val & MSTATUS_WMASK)
            if (v >> MPP_SHIFT) & 3 == 2:  # MPP WARL: only 0/1/3
                v &= ~(3 << MPP_SHIFT)
            c['mstatus'] = v
        elif name == 'hstatus':
            c['hstatus'] = (c['hstatus'] & ~HSTATUS_WMASK) | (val & HSTATUS_WMASK)
        elif name == 'sie':
            c['mie'] = (c['mie'] & ~S_MASK_I) | (val & S_MASK_I)
        elif name == 'sip':
            c['mip'] = (c['mip'] & ~SSIP) | (val & SSIP)
        elif name == 'hie':
            c['mie'] = (c['mie'] & ~HS_MASK_I) | (val & HS_MASK_I)
        elif name == 'hip':
            c['mip'] = (c['mip'] & ~VSSIP) | (val & VSSIP)
        elif name == 'hvip':
            c['mip'] = (c['mip'] & ~VS_MASK_I) | (val & VS_MASK_I)
        elif name == 'vsie':
            bits = (val << 1) & c['hideleg'] & VS_MASK_I
            c['mie'] = (c['mie'] & ~(c['hideleg'] & VS_MASK_I)) | bits
        elif name == 'vsip':
            bit = (val << 1) & c['hideleg'] & VSSIP
            c['mip'] = (c['mip'] & ~(c['hideleg'] & VSSIP)) | bit
        elif name == 'mie':
            c['mie'] = val & (M_MASK_I | S_MASK_I | HS_MASK_I)
        elif name == 'mip':
            mask = SSIP | STIP | SEIP | VS_MASK_I
            c['mip'] = (c['mip'] & ~mask) | (val & mask)
        elif name == 'mideleg':
            c['mideleg'] = val & S_MASK_I
        elif name == 'hideleg':
            c['hideleg'] = val & VS_MASK_I
        elif name == 'medeleg':
            c['medeleg'] = val & MEDELEG_WMASK
        elif name == 'hedeleg':
            c['hedeleg'] = val & HEDELEG_WMASK
        elif name in ('satp', 'vsatp'):
            if val >> 60 in (0, 8):
                c[name] = val
        elif name == 'hgatp':
            if val >> 60 in (0, 8):
                c['hgatp'] = val & ~3  # 16K-aligned root (WARL)
        elif name in ('mtvec', 'stvec', 'vstvec'):
            c[name] = val & ~2
        elif name in ('mepc', 'sepc', 'vsepc'):
            c[name] = val & ~1
        elif name in ('mcounteren', 'scounteren', 'hcounteren'):
            c[name] = val & 7
        elif name == 'hgeie':
            c['hgeie'] = val & HGEIE_MASK
        elif name in ('misa', 'mvendorid', 'marchid', 'mimpid', 'mhartid', 'hgeip'):
            pass  # WARL-fixed / read-only
        else:
            c[name] = val

    # ---------------- traps (trap.rs) ----------------
    def exception_target(self, code):
        if self.prv == 3:
            return 'M'
        if not (self.csr['medeleg'] >> code) & 1:
            return 'M'
        if self.virt and (self.csr['hedeleg'] >> code) & 1:
            return 'VS'
        return 'HS'

    def take_trap(self, t):
        code = t.cause
        target = self.exception_target(code)
        self.exc_counts[(code, target)] = self.exc_counts.get((code, target), 0) + 1
        if self.trap_hook:
            self.trap_hook(code, target, t)
        if target == 'M':
            st = self.csr['mstatus']
            st &= ~(MPV | GVA | (3 << MPP_SHIFT) | MPIE)
            if self.virt:
                st |= MPV
            if t.gva:
                st |= GVA
            st |= self.prv << MPP_SHIFT
            if st & MIE:
                st |= MPIE
            st &= ~MIE
            self.csr['mstatus'] = st
            self.csr['mepc'] = self.pc
            self.csr['mcause'] = code
            self.csr['mtval'] = t.tval
            self.csr['mtval2'] = t.gpa >> 2
            self.csr['mtinst'] = t.tinst
            self.virt = False
            self.prv = 3
            self.pc = self.csr['mtvec'] & ~3
        elif target == 'HS':
            hs = self.csr['hstatus'] & ~(SPV | H_GVA)
            if self.virt:
                hs |= SPV
                hs &= ~SPVP
                if self.prv == 1:
                    hs |= SPVP
            if t.gva:
                hs |= H_GVA
            self.csr['hstatus'] = hs
            st = self.csr['mstatus'] & ~(SPP | SPIE)
            if self.prv == 1:
                st |= SPP
            if st & SIE:
                st |= SPIE
            st &= ~SIE
            self.csr['mstatus'] = st
            self.csr['sepc'] = self.pc
            self.csr['scause'] = code
            self.csr['stval'] = t.tval
            self.csr['htval'] = t.gpa >> 2
            self.csr['htinst'] = t.tinst
            self.virt = False
            self.prv = 1
            self.pc = self.csr['stvec'] & ~3
        else:  # VS
            st = self.csr['vsstatus'] & ~(SPP | SPIE)
            if self.prv == 1:
                st |= SPP
            if st & SIE:
                st |= SPIE
            st &= ~SIE
            self.csr['vsstatus'] = st
            self.csr['vsepc'] = self.pc
            self.csr['vscause'] = code
            self.csr['vstval'] = t.tval
            self.virt = True
            self.prv = 1
            self.pc = self.csr['vstvec'] & ~3

    def mret(self):
        st = self.csr['mstatus']
        mpp = (st >> MPP_SHIFT) & 3
        mpv = bool(st & MPV)
        new = st & ~MIE
        if st & MPIE:
            new |= MIE
        new |= MPIE
        new &= ~((3 << MPP_SHIFT) | MPV)
        if mpp != 3:
            new &= ~MPRV  # MPRV cleared when leaving M
        self.csr['mstatus'] = new
        self.prv = mpp
        self.virt = mpv and mpp != 3
        self.pc = self.csr['mepc']

    def sret(self):
        if self.virt:  # sret_vs
            st = self.csr['vsstatus']
            spp = 1 if st & SPP else 0
            new = st & ~SIE
            if st & SPIE:
                new |= SIE
            new |= SPIE
            new &= ~SPP
            self.csr['vsstatus'] = new
            self.prv = spp
            self.pc = self.csr['vsepc']
        else:  # sret_hs
            st = self.csr['mstatus']
            spp = 1 if st & SPP else 0
            spv = bool(self.csr['hstatus'] & SPV)
            new = st & ~SIE
            if st & SPIE:
                new |= SIE
            new |= SPIE
            new &= ~(SPP | MPRV)
            self.csr['mstatus'] = new
            self.csr['hstatus'] &= ~SPV
            if spv:
                self.prv = 1 if self.csr['hstatus'] & SPVP else 0
            else:
                self.prv = spp
            self.virt = spv
            self.pc = self.csr['sepc']

    # ---------------- data access ----------------
    def data_env(self):
        """Effective (prv, virt) for loads/stores: mstatus.MPRV substitutes
        MPP/MPV while in M-mode (execute.rs data_access_env)."""
        st = self.csr['mstatus']
        if self.prv == 3 and st & MPRV:
            mpp = (st >> MPP_SHIFT) & 3
            mpv = bool(st & MPV) and mpp != 3
            return mpp, mpv
        return self.prv, self.virt

    def load(self, va, size, signed=False, hlvx=False, forced=False,
             prv=None, virt=None, tinst=0):
        # Misaligned accesses are fine within a page; page-crossers trap.
        if (va & 0xFFF) + size > 0x1000 and va % size != 0:
            raise Trap(4, va)
        if prv is None and not forced:
            prv, virt = self.data_env()
        pa = self.translate(va, 'r', prv=prv, virt=virt, hlvx=hlvx,
                            forced=forced, tinst=tinst)
        try:
            v = self.pread(pa, size)
        except Trap as t:
            t.tval = va
            raise
        if signed:
            v = sext(v, 8 * size) & M64
        return v

    def store(self, va, size, val, forced=False, prv=None, virt=None, tinst=0):
        if (va & 0xFFF) + size > 0x1000 and va % size != 0:
            raise Trap(6, va)
        if prv is None and not forced:
            prv, virt = self.data_env()
        pa = self.translate(va, 'w', prv=prv, virt=virt, forced=forced, tinst=tinst)
        try:
            self.pwrite(pa, size, val)
        except Trap as t:
            t.tval = va
            raise

    # ---------------- raw encodings for tval/tinst ----------------
    @staticmethod
    def _enc_csr(head, ops):
        if head in ('csrw', 'csrs', 'csrc'):
            name, rd, rs1 = ops[0], 0, reg(ops[1])
            f3 = {'csrw': 1, 'csrs': 2, 'csrc': 3}[head]
        elif head == 'csrr':
            name, rd, rs1 = ops[1], reg(ops[0]), 0
            f3 = 2
        else:  # csrrw / csrrs / csrrc
            name, rd, rs1 = ops[1], reg(ops[0]), reg(ops[2])
            f3 = {'csrrw': 1, 'csrrs': 2, 'csrrc': 3}[head]
        addr = CSR_ADDR.get(name.strip().lower())
        if addr is None:
            raise RuntimeError(f"emulator: unknown CSR {name!r}")
        return (addr << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | 0x73

    # ---------------- execute ----------------
    def set_reg(self, r, v):
        if r != 0:
            self.regs[r] = v & M64

    def step(self):
        """Execute one IR statement. Returns the statement's byte size when
        it retires, or None when it traps (matching Rust minstret rules:
        control flow retires, exceptions don't)."""
        try:
            pa = self.translate(self.pc, 'x')
        except Trap as t:
            self.take_trap(t)
            return None
        ent = self.ir.get(pa)
        if ent is None:
            raise RuntimeError(f"fetch of non-code address pc={self.pc:#x} pa={pa:#x}")
        ln, head, ops, size, syms = ent
        rg = self.regs
        nxt = (self.pc + size) & M64

        def ev(s):
            return eval_expr(s, syms) & M64

        try:
            if head == 'li':
                self.set_reg(reg(ops[0]), ev(ops[1]))
            elif head == 'la':
                # auipc-based: target computed from link-time delta
                target = ev(ops[1])
                link_pc = pa  # IR keyed by link address
                delta = (target - link_pc) & M64
                self.set_reg(reg(ops[0]), (self.pc + delta) & M64)
            elif head == 'mv':
                self.set_reg(reg(ops[0]), rg[reg(ops[1])])
            elif head == 'neg':
                self.set_reg(reg(ops[0]), (-rg[reg(ops[1])]) & M64)
            elif head == 'not':
                self.set_reg(reg(ops[0]), (~rg[reg(ops[1])]) & M64)
            elif head == 'sext.w':
                self.set_reg(reg(ops[0]), sext(rg[reg(ops[1])], 32) & M64)
            elif head in ('seqz', 'snez'):
                a = rg[reg(ops[1])]
                self.set_reg(reg(ops[0]), int(a == 0) if head == 'seqz' else int(a != 0))
            elif head in ('add', 'sub', 'and', 'or', 'xor', 'mul', 'divu', 'remu',
                          'srl', 'sll', 'sra', 'slt', 'sltu'):
                a, b = rg[reg(ops[1])], rg[reg(ops[2])]
                if head == 'add':
                    v = a + b
                elif head == 'sub':
                    v = a - b
                elif head == 'and':
                    v = a & b
                elif head == 'or':
                    v = a | b
                elif head == 'xor':
                    v = a ^ b
                elif head == 'mul':
                    v = a * b
                elif head == 'divu':
                    v = M64 if b == 0 else a // b
                elif head == 'remu':
                    v = a if b == 0 else a % b
                elif head == 'srl':
                    v = a >> (b & 63)
                elif head == 'sll':
                    v = a << (b & 63)
                elif head == 'sra':
                    v = sext(a, 64) >> (b & 63)
                elif head == 'slt':
                    v = int(sext(a, 64) < sext(b, 64))
                else:
                    v = int(a < b)
                self.set_reg(reg(ops[0]), v & M64)
            elif head in ('addw', 'subw', 'sllw', 'srlw', 'sraw'):
                a, b = rg[reg(ops[1])], rg[reg(ops[2])]
                sh = b & 31
                if head == 'addw':
                    v = a + b
                elif head == 'subw':
                    v = a - b
                elif head == 'sllw':
                    v = a << sh
                elif head == 'srlw':
                    v = (a & 0xFFFF_FFFF) >> sh
                else:
                    v = sext(a, 32) >> sh
                self.set_reg(reg(ops[0]), sext(v, 32) & M64)
            elif head in ('addi', 'andi', 'ori', 'xori', 'slti', 'sltiu'):
                a = rg[reg(ops[1])]
                imm = sext(ev(ops[2]), 64) & M64
                if head == 'addi':
                    v = a + imm
                elif head == 'andi':
                    v = a & imm
                elif head == 'ori':
                    v = a | imm
                elif head == 'xori':
                    v = a ^ imm
                elif head == 'slti':
                    v = int(sext(a, 64) < sext(imm, 64))
                else:
                    v = int(a < imm)
                self.set_reg(reg(ops[0]), v & M64)
            elif head == 'addiw':
                v = rg[reg(ops[1])] + (sext(ev(ops[2]), 64) & M64)
                self.set_reg(reg(ops[0]), sext(v, 32) & M64)
            elif head == 'slli':
                self.set_reg(reg(ops[0]), (rg[reg(ops[1])] << (ev(ops[2]) & 63)) & M64)
            elif head == 'srli':
                self.set_reg(reg(ops[0]), rg[reg(ops[1])] >> (ev(ops[2]) & 63))
            elif head == 'srai':
                self.set_reg(reg(ops[0]), (sext(rg[reg(ops[1])], 64) >> (ev(ops[2]) & 63)) & M64)
            elif head in ('slliw', 'srliw', 'sraiw'):
                a, sh = rg[reg(ops[1])], ev(ops[2]) & 31
                if head == 'slliw':
                    v = a << sh
                elif head == 'srliw':
                    v = (a & 0xFFFF_FFFF) >> sh
                else:
                    v = sext(a, 32) >> sh
                self.set_reg(reg(ops[0]), sext(v, 32) & M64)
            elif head in LOAD_F3:
                off, base = mem_operand(ops[1], syms)
                rd = reg(ops[0])
                va = (rg[base] + off) & M64
                raw = ((off & 0xFFF) << 20) | (base << 15) | (LOAD_F3[head] << 12) | (rd << 7) | 0x03
                size_b = {'lb': 1, 'lh': 2, 'lw': 4, 'ld': 8, 'lbu': 1, 'lhu': 2, 'lwu': 4}[head]
                signed = head in ('lb', 'lh', 'lw')
                v = self.load(va, size_b, signed=signed, tinst=raw & ~(0x1F << 15))
                self.set_reg(rd, v)
            elif head in STORE_F3:
                off, base = mem_operand(ops[1], syms)
                rs2 = reg(ops[0])
                va = (rg[base] + off) & M64
                raw = (((off >> 5) & 0x7F) << 25) | (rs2 << 20) | (base << 15) \
                    | (STORE_F3[head] << 12) | ((off & 0x1F) << 7) | 0x23
                size_b = {'sb': 1, 'sh': 2, 'sw': 4, 'sd': 8}[head]
                self.store(va, size_b, rg[rs2], tinst=raw & ~(0x1F << 15))
            elif head in HLV_CODE:
                f7, rs2c = HLV_CODE[head]
                rd = reg(ops[0])
                off, base = mem_operand(ops[1], syms)
                raw = (f7 << 25) | (rs2c << 20) | (base << 15) | (4 << 12) | (rd << 7) | 0x73
                if self.virt:
                    raise Trap(22, raw)
                if self.prv == 0 and not (self.csr['hstatus'] & HU):
                    raise Trap(2, raw)
                eprv = 1 if self.csr['hstatus'] & SPVP else 0
                size_b, signed, hlvx = HLV_META[head]
                va = (rg[base] + off) & M64
                v = self.load(va, size_b, signed=signed, hlvx=hlvx, forced=True,
                              prv=eprv, virt=True, tinst=raw & ~(0x1F << 15))
                self.set_reg(rd, v)
            elif head in HSV_CODE:
                rs2 = reg(ops[0])
                off, base = mem_operand(ops[1], syms)
                raw = (HSV_CODE[head] << 25) | (rs2 << 20) | (base << 15) | (4 << 12) | 0x73
                if self.virt:
                    raise Trap(22, raw)
                if self.prv == 0 and not (self.csr['hstatus'] & HU):
                    raise Trap(2, raw)
                eprv = 1 if self.csr['hstatus'] & SPVP else 0
                va = (rg[base] + off) & M64
                self.store(va, HSV_SIZE[head], rg[rs2], forced=True,
                           prv=eprv, virt=True, tinst=raw & ~(0x1F << 15))
            elif head in ('beq', 'bne', 'blt', 'bltu', 'bgeu', 'bge', 'bgt', 'ble', 'bgtu', 'bleu'):
                a, b = rg[reg(ops[0])], rg[reg(ops[1])]
                sa, sb = sext(a, 64), sext(b, 64)
                take = {'beq': a == b, 'bne': a != b, 'blt': sa < sb, 'bltu': a < b,
                        'bgeu': a >= b, 'bge': sa >= sb, 'bgt': sa > sb, 'ble': sa <= sb,
                        'bgtu': a > b, 'bleu': a <= b}[head]
                if take:
                    nxt = (self.pc + (ev(ops[2]) - pa)) & M64
            elif head in ('beqz', 'bnez', 'bgez', 'bltz', 'blez', 'bgtz'):
                a = sext(rg[reg(ops[0])], 64)
                take = {'beqz': a == 0, 'bnez': a != 0, 'bgez': a >= 0,
                        'bltz': a < 0, 'blez': a <= 0, 'bgtz': a > 0}[head]
                if take:
                    nxt = (self.pc + (ev(ops[1]) - pa)) & M64
            elif head in ('j', 'tail'):
                nxt = (self.pc + (ev(ops[0]) - pa)) & M64
            elif head in ('jal', 'call'):
                target = ops[-1]
                rd = 1 if head == 'call' or len(ops) == 1 else reg(ops[0])
                self.set_reg(rd, nxt)
                nxt = (self.pc + (ev(target) - pa)) & M64
            elif head == 'ret':
                nxt = rg[1]
            elif head == 'jr':
                nxt = rg[reg(ops[0])]
            elif head in ('csrw', 'csrr', 'csrs', 'csrc', 'csrrw', 'csrrs', 'csrrc'):
                raw = self._enc_csr(head, ops)
                if head in ('csrw', 'csrs', 'csrc'):
                    name, rd, rs = ops[0].strip().lower(), 0, reg(ops[1])
                elif head == 'csrr':
                    name, rd, rs = ops[1].strip().lower(), reg(ops[0]), 0
                else:
                    name, rd, rs = ops[1].strip().lower(), reg(ops[0]), reg(ops[2])
                # TVM/VTVM gating for satp (execute.rs exec_csr).
                if name == 'satp':
                    if self.prv == 1 and not self.virt and self.csr['mstatus'] & TVM:
                        raise Trap(2, raw)
                    if self.prv == 1 and self.virt and self.csr['hstatus'] & VTVM:
                        raise Trap(22, raw)
                write = head in ('csrw', 'csrrw')
                ename = self.csr_check(name, raw, write)
                old = self.csr_read(ename)
                if head in ('csrw', 'csrrw'):
                    do_write, new = True, rg[rs]
                elif head in ('csrs', 'csrrs'):
                    do_write, new = rs != 0, old | rg[rs]
                else:  # csrc / csrrc
                    do_write, new = rs != 0, old & ~rg[rs]
                if do_write:
                    # Re-check with write intent (read-only CSR via csrs rs!=0).
                    self.csr_check(name, raw, True)
                    self.csr_write(ename, new)
                self.set_reg(rd, old)
            elif head == 'ecall':
                cause = {(0, False): 8, (0, True): 8, (1, False): 9, (1, True): 10,
                         (3, False): 11, (3, True): 11}[(self.prv, self.virt)]
                raise Trap(cause, 0)
            elif head == 'ebreak':
                raise Trap(3, self.pc)
            elif head == 'mret':
                if self.prv != 3:
                    raise Trap(2, RAW_MRET)
                self.mret()
                nxt = self.pc
            elif head == 'sret':
                if self.prv == 0:
                    raise Trap(22 if self.virt else 2, RAW_SRET)
                if self.prv == 1 and not self.virt and self.csr['mstatus'] & TSR:
                    raise Trap(2, RAW_SRET)
                if self.prv == 1 and self.virt and self.csr['hstatus'] & VTSR:
                    raise Trap(22, RAW_SRET)
                self.sret()
                nxt = self.pc
            elif head == 'wfi':
                if self.prv != 3 and self.csr['mstatus'] & TW:
                    raise Trap(2, RAW_WFI)
                if self.virt:
                    if self.prv == 0:
                        raise Trap(22, RAW_WFI)
                    if self.csr['hstatus'] & VTW:
                        raise Trap(22, RAW_WFI)
                # Legal WFI: no interrupts are modeled, treat as nop.
            elif head in ('sfence.vma', 'hfence.vvma', 'hfence.gvma'):
                rs1 = reg(ops[0]) if len(ops) >= 1 else 0
                rs2 = reg(ops[1]) if len(ops) >= 2 else 0
                raw = (FENCE_F7[head] << 25) | (rs2 << 20) | (rs1 << 15) | 0x73
                if head == 'sfence.vma':
                    if self.prv == 0:
                        raise Trap(22 if self.virt else 2, raw)
                    if self.prv == 1 and not self.virt and self.csr['mstatus'] & TVM:
                        raise Trap(2, raw)
                    if self.prv == 1 and self.virt and self.csr['hstatus'] & VTVM:
                        raise Trap(22, raw)
                else:
                    if self.virt:
                        raise Trap(22, raw)
                    if self.prv == 0:
                        raise Trap(2, raw)
                    if (head == 'hfence.gvma' and self.prv == 1
                            and self.csr['mstatus'] & TVM):
                        raise Trap(2, raw)
                # No TLB is modeled: a legal fence is a no-op.
            elif head in ('fence', 'fence.i', 'nop'):
                pass
            else:
                raise RuntimeError(f"emulator: unhandled mnemonic {head!r} at line {ln}")
        except Trap as t:
            self.take_trap(t)
            return None
        self.pc = nxt
        self.insts += 1
        return size

    def run(self, max_steps):
        for _ in range(max_steps):
            if self.poweroff is not None:
                return 'poweroff'
            self.step()
        return 'limit'
