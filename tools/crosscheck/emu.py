#!/usr/bin/env python3
"""RV64 M/HS/VS/U emulator over the asm2ir IR, mirroring hvsim's Rust
semantics (cpu/trap.rs, cpu/csr.rs redirection, mmu/walker.rs two-stage
Sv39/Sv39x4). Used to cross-check the embedded software stack offline."""
import os, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble, sext, eval_expr, reg, mem_operand

M64 = (1 << 64) - 1
RAM_BASE = 0x8000_0000
UART = 0x1000_0000
SYSCON = 0x10_0000

# mstatus bits
SIE, MIE, SPIE, MPIE, SPP = 1 << 1, 1 << 3, 1 << 5, 1 << 7, 1 << 8
MPP_SHIFT = 11
SUM_BIT, MXR = 1 << 18, 1 << 19
MPV, GVA = 1 << 39, 1 << 38
# hstatus bits
H_GVA, SPV, SPVP = 1 << 6, 1 << 7, 1 << 8

class Trap(Exception):
    def __init__(self, cause, tval, gpa=0, gva=False):
        self.cause, self.tval, self.gpa, self.gva = cause, tval, gpa, gva

class Machine:
    def __init__(self, ram_mb=64):
        self.ram = bytearray(ram_mb << 20)
        self.regs = [0] * 32
        self.pc = 0
        self.prv = 3
        self.virt = False
        self.csr = {n: 0 for n in (
            'mstatus vsstatus medeleg mideleg hedeleg hideleg mie mip mtvec stvec vstvec '
            'mscratch sscratch vsscratch mepc sepc vsepc mcause scause vscause mtval stval '
            'vstval mtval2 htval mtinst htinst satp vsatp hgatp hstatus htimedelta '
            'mcounteren scounteren hcounteren'
        ).split()}
        self.uart = bytearray()
        self.poweroff = None
        self.ir = {}
        self.insts = 0
        self.exc_counts = {}

    # ---------------- physical memory ----------------
    def pread(self, pa, size):
        if RAM_BASE <= pa and pa + size <= RAM_BASE + len(self.ram):
            off = pa - RAM_BASE
            return int.from_bytes(self.ram[off:off + size], 'little')
        if pa == SYSCON:
            return 0
        raise Trap(5, pa)  # load access fault (approx)

    def pwrite(self, pa, size, val):
        if RAM_BASE <= pa and pa + size <= RAM_BASE + len(self.ram):
            off = pa - RAM_BASE
            self.ram[off:off + size] = (val & ((1 << (8 * size)) - 1)).to_bytes(size, 'little')
            return
        if UART <= pa < UART + 0x100:
            if pa == UART:
                self.uart.append(val & 0xFF)
            return
        if pa == SYSCON:
            self.poweroff = val & 0xFFFFFFFF
            return
        raise Trap(7, pa)

    # ---------------- translation (walker.rs) ----------------
    def walk_g(self, va, gpa, access, implicit):
        cause = {'x': 20, 'r': 21, 'w': 23}[access]
        if gpa >> 41:
            raise Trap(cause, va, gpa, True)
        a = (self.csr['hgatp'] & ((1 << 44) - 1)) << 12
        level = 2
        while True:
            idx = (gpa >> 30) & 0x7FF if level == 2 else (gpa >> (12 + 9 * level)) & 0x1FF
            raw = self.pread(a + idx * 8, 8)
            perms = raw & 0xFF
            ppn = (raw >> 10) & ((1 << 44) - 1)
            V, R, W, X, U, A, D = (perms & 1, perms & 2, perms & 4, perms & 8,
                                   perms & 16, perms & 64, perms & 128)
            if not V or (not R and W):
                raise Trap(cause, va, gpa, True)
            if R or X:
                span = (1 << (9 * level)) - 1
                if ppn & span:
                    raise Trap(cause, va, gpa, True)
                if implicit and (not U or not R or not A):
                    raise Trap(cause, va, gpa, True)
                # final-access perms checked here for the non-implicit case
                if not implicit:
                    if not U:
                        raise Trap(cause, va, gpa, True)
                    ok = {'x': X, 'r': R, 'w': W}[access]
                    if not ok:
                        raise Trap(cause, va, gpa, True)
                    if not A or (access == 'w' and not D):
                        raise Trap(cause, va, gpa, True)
                page = (ppn & ~span) | ((gpa >> 12) & span)
                return (page << 12) | (gpa & 0xFFF)
            if perms & (16 | 64 | 128):
                raise Trap(cause, va, gpa, True)
            level -= 1
            if level < 0:
                raise Trap(cause, va, gpa, True)
            a = ppn << 12

    def translate(self, va, access, prv=None, virt=None):
        prv = self.prv if prv is None else prv
        virt = self.virt if virt is None else virt
        cause1 = {'x': 12, 'r': 13, 'w': 15}[access]
        if virt:
            s1_atp = self.csr['vsatp']
            s1_on = (s1_atp >> 60) == 8
        elif prv == 3:
            s1_on, s1_atp = False, 0
        else:
            s1_atp = self.csr['satp']
            s1_on = (s1_atp >> 60) == 8
        s2_on = virt and (self.csr['hgatp'] >> 60) == 8
        if not s1_on and not s2_on:
            return va
        if s1_on:
            if sext(va, 39) & M64 != va:
                raise Trap(cause1, va, 0, virt)
            a = (s1_atp & ((1 << 44) - 1)) << 12
            level = 2
            while True:
                idx = (va >> (12 + 9 * level)) & 0x1FF
                pte_addr = a + idx * 8
                pte_pa = self.walk_g(va, pte_addr, 'r', True) if s2_on else pte_addr
                raw = self.pread(pte_pa, 8)
                perms = raw & 0xFF
                ppn = (raw >> 10) & ((1 << 44) - 1)
                V, R, W, X, U, A, D = (perms & 1, perms & 2, perms & 4, perms & 8,
                                       perms & 16, perms & 64, perms & 128)
                if not V or (not R and W):
                    raise Trap(cause1, va, 0, virt)
                if R or X:
                    span = (1 << (9 * level)) - 1
                    if ppn & span:
                        raise Trap(cause1, va, 0, virt)
                    # stage-1 permission check (tlb.rs check_permissions)
                    st = self.csr['vsstatus'] if virt else self.csr['mstatus']
                    sum_ok = bool(st & SUM_BIT)
                    user = prv == 0
                    if user and not U:
                        raise Trap(cause1, va, 0, virt)
                    if not user and U and (not sum_ok or access == 'x'):
                        raise Trap(cause1, va, 0, virt)
                    ok = {'x': X, 'r': R, 'w': W}[access]
                    if not ok:
                        raise Trap(cause1, va, 0, virt)
                    if not A or (access == 'w' and not D):
                        raise Trap(cause1, va, 0, virt)
                    page = (ppn & ~span) | ((va >> 12) & span)
                    gpa = (page << 12) | (va & 0xFFF)
                    break
                if perms & (16 | 64 | 128):
                    raise Trap(cause1, va, 0, virt)
                level -= 1
                if level < 0:
                    raise Trap(cause1, va, 0, virt)
                a = ppn << 12
        else:
            gpa = va
        if s2_on:
            return self.walk_g(va, gpa, access, False)
        return gpa

    # ---------------- CSR access (csr.rs redirection subset) --------------
    REDIR = {'sstatus': 'vsstatus', 'stvec': 'vstvec', 'sscratch': 'vsscratch',
             'sepc': 'vsepc', 'scause': 'vscause', 'stval': 'vstval',
             'satp': 'vsatp', 'sie': 'vsie', 'sip': 'vsip'}
    SSTATUS_MASK = SIE | SPIE | SPP | SUM_BIT | MXR | (3 << 13)

    def csr_read(self, name):
        if self.virt and name in self.REDIR:
            name = self.REDIR[name]
        if name == 'sstatus':
            return self.csr['mstatus'] & self.SSTATUS_MASK
        if name == 'vsstatus':
            return self.csr['vsstatus'] & self.SSTATUS_MASK
        if name == 'mip' or name == 'mie':
            return self.csr[name]
        return self.csr[name]

    def csr_write(self, name, val):
        if self.virt and name in self.REDIR:
            name = self.REDIR[name]
        if name == 'sstatus':
            self.csr['mstatus'] = (self.csr['mstatus'] & ~self.SSTATUS_MASK) | (val & self.SSTATUS_MASK)
            return
        if name == 'vsstatus':
            self.csr['vsstatus'] = (self.csr['vsstatus'] & ~self.SSTATUS_MASK) | (val & self.SSTATUS_MASK)
            return
        if name in ('satp', 'vsatp', 'hgatp'):
            mode = val >> 60
            if mode in (0, 8):
                self.csr[name] = val & ~(3 if name == 'hgatp' else 0)
            return
        if name == 'medeleg':
            wmask = 0xB109 | (1 << 4) | (1 << 6) | (1 << 9) | (1 << 10) | (0xF << 20)
            self.csr[name] = val & wmask
            return
        if name == 'hedeleg':
            wmask = (0x1FF | (1 << 12) | (1 << 13) | (1 << 15))
            self.csr[name] = val & wmask
            return
        if name == 'hstatus':
            wmask = H_GVA | SPV | SPVP | (1 << 9) | (0x3F << 12) | (7 << 20)
            self.csr[name] = (self.csr[name] & ~wmask) | (val & wmask)
            return
        self.csr[name] = val & M64

    # ---------------- traps (trap.rs) ----------------
    def exception_target(self, code):
        if self.prv == 3:
            return 'M'
        if not (self.csr['medeleg'] >> code) & 1:
            return 'M'
        if self.virt and (self.csr['hedeleg'] >> code) & 1:
            return 'VS'
        return 'HS'

    def take_trap(self, t):
        code = t.cause
        target = self.exception_target(code)
        self.exc_counts[(code, target)] = self.exc_counts.get((code, target), 0) + 1
        if target == 'M':
            st = self.csr['mstatus']
            st &= ~(MPV | GVA | (3 << MPP_SHIFT) | MPIE)
            if self.virt:
                st |= MPV
            if t.gva:
                st |= GVA
            st |= self.prv << MPP_SHIFT
            if st & MIE:
                st |= MPIE
            st &= ~MIE
            self.csr['mstatus'] = st
            self.csr['mepc'] = self.pc
            self.csr['mcause'] = code
            self.csr['mtval'] = t.tval
            self.csr['mtval2'] = t.gpa >> 2
            self.virt = False
            self.prv = 3
            self.pc = self.csr['mtvec'] & ~3
        elif target == 'HS':
            hs = self.csr['hstatus'] & ~(SPV | H_GVA)
            if self.virt:
                hs |= SPV
                hs &= ~SPVP
                if self.prv == 1:
                    hs |= SPVP
            if t.gva:
                hs |= H_GVA
            self.csr['hstatus'] = hs
            st = self.csr['mstatus'] & ~(SPP | SPIE)
            if self.prv == 1:
                st |= SPP
            if st & SIE:
                st |= SPIE
            st &= ~SIE
            self.csr['mstatus'] = st
            self.csr['sepc'] = self.pc
            self.csr['scause'] = code
            self.csr['stval'] = t.tval
            self.csr['htval'] = t.gpa >> 2
            self.virt = False
            self.prv = 1
            self.pc = self.csr['stvec'] & ~3
        else:  # VS
            st = self.csr['vsstatus'] & ~(SPP | SPIE)
            if self.prv == 1:
                st |= SPP
            if st & SIE:
                st |= SPIE
            st &= ~SIE
            self.csr['vsstatus'] = st
            self.csr['vsepc'] = self.pc
            self.csr['vscause'] = code
            self.csr['vstval'] = t.tval
            self.virt = True
            self.prv = 1
            self.pc = self.csr['vstvec'] & ~3

    def mret(self):
        st = self.csr['mstatus']
        mpp = (st >> MPP_SHIFT) & 3
        mpv = bool(st & MPV)
        new = st & ~MIE
        if st & MPIE:
            new |= MIE
        new |= MPIE
        new &= ~((3 << MPP_SHIFT) | MPV)
        self.csr['mstatus'] = new
        self.prv = mpp
        self.virt = mpv and mpp != 3
        self.pc = self.csr['mepc']

    def sret(self):
        if self.virt:  # sret_vs
            st = self.csr['vsstatus']
            spp = 1 if st & SPP else 0
            new = st & ~SIE
            if st & SPIE:
                new |= SIE
            new |= SPIE
            new &= ~SPP
            self.csr['vsstatus'] = new
            self.prv = spp
            self.pc = self.csr['vsepc']
        else:  # sret_hs
            st = self.csr['mstatus']
            spp = 1 if st & SPP else 0
            spv = bool(self.csr['hstatus'] & SPV)
            new = st & ~SIE
            if st & SPIE:
                new |= SIE
            new |= SPIE
            new &= ~SPP
            self.csr['mstatus'] = new
            self.csr['hstatus'] &= ~SPV
            if spv:
                self.prv = 1 if self.csr['hstatus'] & SPVP else 0
            else:
                self.prv = spp
            self.virt = spv
            self.pc = self.csr['sepc']

    # ---------------- data access ----------------
    def load(self, va, size, signed=False):
        pa = self.translate(va, 'r')
        v = self.pread(pa, size)
        if signed:
            v = sext(v, 8 * size) & M64
        return v

    def store(self, va, size, val):
        pa = self.translate(va, 'w')
        self.pwrite(pa, size, val)

    # ---------------- execute ----------------
    def set_reg(self, r, v):
        if r != 0:
            self.regs[r] = v & M64

    def step(self):
        try:
            pa = self.translate(self.pc, 'x')
        except Trap as t:
            self.take_trap(t)
            return
        ent = self.ir.get(pa)
        if ent is None:
            raise RuntimeError(f"fetch of non-code address pc={self.pc:#x} pa={pa:#x}")
        ln, head, ops, size, syms = ent
        rg = self.regs
        nxt = (self.pc + size) & M64

        def ev(s):
            return eval_expr(s, syms) & M64

        try:
            if head == 'li':
                self.set_reg(reg(ops[0]), ev(ops[1]))
            elif head == 'la':
                # auipc-based: target computed from link-time delta
                target = ev(ops[1])
                link_pc = pa  # IR keyed by link address
                delta = (target - link_pc) & M64
                self.set_reg(reg(ops[0]), (self.pc + delta) & M64)
            elif head == 'mv':
                self.set_reg(reg(ops[0]), rg[reg(ops[1])])
            elif head == 'neg':
                self.set_reg(reg(ops[0]), (-rg[reg(ops[1])]) & M64)
            elif head in ('add', 'sub', 'and', 'or', 'xor', 'mul', 'divu', 'remu', 'srl', 'sll'):
                a, b = rg[reg(ops[1])], rg[reg(ops[2])]
                if head == 'add':
                    v = a + b
                elif head == 'sub':
                    v = a - b
                elif head == 'and':
                    v = a & b
                elif head == 'or':
                    v = a | b
                elif head == 'xor':
                    v = a ^ b
                elif head == 'mul':
                    v = a * b
                elif head == 'divu':
                    v = M64 if b == 0 else a // b
                elif head == 'remu':
                    v = a if b == 0 else a % b
                elif head == 'srl':
                    v = a >> (b & 63)
                else:
                    v = a << (b & 63)
                self.set_reg(reg(ops[0]), v & M64)
            elif head in ('addi', 'andi', 'ori', 'xori'):
                a = rg[reg(ops[1])]
                imm = sext(ev(ops[2]), 64) & M64
                if head == 'addi':
                    v = a + imm
                elif head == 'andi':
                    v = a & imm
                elif head == 'ori':
                    v = a | imm
                else:
                    v = a ^ imm
                self.set_reg(reg(ops[0]), v & M64)
            elif head == 'slli':
                self.set_reg(reg(ops[0]), (rg[reg(ops[1])] << (ev(ops[2]) & 63)) & M64)
            elif head == 'srli':
                self.set_reg(reg(ops[0]), rg[reg(ops[1])] >> (ev(ops[2]) & 63))
            elif head == 'srai':
                self.set_reg(reg(ops[0]), (sext(rg[reg(ops[1])], 64) >> (ev(ops[2]) & 63)) & M64)
            elif head in ('ld', 'lw', 'lbu'):
                off, base = mem_operand(ops[1], syms)
                va = (rg[base] + off) & M64
                if head == 'ld':
                    v = self.load(va, 8)
                elif head == 'lw':
                    v = self.load(va, 4, signed=True)
                else:
                    v = self.load(va, 1)
                self.set_reg(reg(ops[0]), v)
            elif head in ('sd', 'sw', 'sb'):
                off, base = mem_operand(ops[1], syms)
                va = (rg[base] + off) & M64
                size_b = {'sd': 8, 'sw': 4, 'sb': 1}[head]
                self.store(va, size_b, rg[reg(ops[0])])
            elif head in ('beq', 'bne', 'blt', 'bltu', 'bgeu', 'bgt', 'ble', 'bgtu', 'bleu'):
                a, b = rg[reg(ops[0])], rg[reg(ops[1])]
                sa, sb = sext(a, 64), sext(b, 64)
                take = {'beq': a == b, 'bne': a != b, 'blt': sa < sb, 'bltu': a < b,
                        'bgeu': a >= b, 'bgt': sa > sb, 'ble': sa <= sb,
                        'bgtu': a > b, 'bleu': a <= b}[head]
                if take:
                    self.pc = self.pc + (ev(ops[2]) - pa)
                    return
            elif head in ('beqz', 'bnez', 'bgez', 'bltz', 'blez', 'bgtz'):
                a = sext(rg[reg(ops[0])], 64)
                take = {'beqz': a == 0, 'bnez': a != 0, 'bgez': a >= 0,
                        'bltz': a < 0, 'blez': a <= 0, 'bgtz': a > 0}[head]
                if take:
                    self.pc = self.pc + (ev(ops[1]) - pa)
                    return
            elif head in ('j', 'tail'):
                self.pc = self.pc + (ev(ops[0]) - pa)
                return
            elif head in ('jal', 'call'):
                target = ops[-1]
                rd = 1 if head == 'call' or len(ops) == 1 else reg(ops[0])
                self.set_reg(rd, nxt)
                self.pc = self.pc + (ev(target) - pa)
                return
            elif head == 'ret':
                self.pc = rg[1]
                return
            elif head == 'jr':
                self.pc = rg[reg(ops[0])]
                return
            elif head == 'csrw':
                self.csr_write(ops[0], rg[reg(ops[1])])
            elif head == 'csrr':
                self.set_reg(reg(ops[0]), self.csr_read(ops[1]))
            elif head == 'csrs':
                self.csr_write(ops[0], self.csr_read(ops[0]) | rg[reg(ops[1])])
            elif head == 'csrc':
                self.csr_write(ops[0], self.csr_read(ops[0]) & ~rg[reg(ops[1])])
            elif head == 'csrrw':
                old = self.csr_read(ops[1])
                self.csr_write(ops[1], rg[reg(ops[2])])
                self.set_reg(reg(ops[0]), old)
            elif head == 'ecall':
                cause = {(0, False): 8, (0, True): 8, (1, False): 9, (1, True): 10,
                         (3, False): 11, (3, True): 11}[(self.prv, self.virt)]
                raise Trap(cause, 0)
            elif head == 'mret':
                self.mret()
                return
            elif head == 'sret':
                self.sret()
                return
            elif head in ('sfence.vma', 'hfence.gvma', 'hfence.vvma', 'fence', 'fence.i', 'nop'):
                pass
            elif head == 'wfi':
                raise RuntimeError("wfi reached (stack should never wfi)")
            else:
                raise RuntimeError(f"emulator: unhandled mnemonic {head!r} at line {ln}")
        except Trap as t:
            self.take_trap(t)
            return
        self.pc = nxt
        self.insts += 1

    def run(self, max_steps):
        for _ in range(max_steps):
            if self.poweroff is not None:
                return 'poweroff'
            self.step()
        return 'limit'
