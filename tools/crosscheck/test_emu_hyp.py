#!/usr/bin/env python3
"""Cross-validate the Python oracle (emu.py) against the hypervisor
semantics pinned by rust/tests/riscv_hyp_tests.rs: the same worlds —
two-stage Sv39/Sv39x4 translation, HLV/HSV/HLVX under every privilege
gate, the per-stage MXR rules, HFENCE/WFI/SRET legality matrices, and
the trap CSR writes (mstatus.GVA/MPV, hstatus.SPV/SPVP, htval/mtval2,
htinst/mtinst) — must produce the same causes, targets, and CSR values
here as the Rust tests assert over cpu/{execute,trap}.rs and
mmu/{walker,tlb}.rs. Run directly: python3 test_emu_hyp.py"""
import os, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from asm2ir import assemble
from emu import (Machine, RAM_BASE, MPV, GVA, H_GVA, SPV, SPVP, HU, MXR,
                 SUM_BIT, TW, TSR, TVM, VTW, VTSR, VTVM, VSSIP, SGEIP,
                 VS_MASK_I, MPP_SHIFT, MPRV, TINST_PSEUDO_PTE_READ, CSR_ADDR)

# pte perms
V, R, W, X, U, A, D = 1, 2, 4, 8, 16, 64, 128
RWXAD = V | R | W | X | A | D          # 0xcf
RWXADU = RWXAD | U                     # 0xdf
XO_U = V | X | A | U                   # execute-only leaf (G / user VS)
XO_AD_U = V | X | A | D | U
HOST_OFF = 0x100_0000                  # G-stage backing offset (world_two_stage)
VMID_SHIFT = ASID_SHIFT = 44
TRAMPOLINE = RAM_BASE + 0xF000


class World:
    """Python twin of riscv_hyp_tests.rs `World`."""

    def __init__(self):
        self.m = Machine(ram_mb=32)
        self.alloc = RAM_BASE + 0x40_0000
        self.gpa_alloc = RAM_BASE + 0x28_0000
        self.traps = []
        self.m.trap_hook = lambda code, target, t: self.traps.append(
            (code, target, t.tval, t.gpa, t.gva, t.tinst))
        self.m.csr['mtvec'] = TRAMPOLINE

    # -- physical helpers --
    def w64(self, pa, val):
        off = pa - RAM_BASE
        self.m.ram[off:off + 8] = (val & ((1 << 64) - 1)).to_bytes(8, 'little')

    def r64(self, pa):
        off = pa - RAM_BASE
        return int.from_bytes(self.m.ram[off:off + 8], 'little')

    def alloc_page(self, bytes_=0x1000):
        self.alloc = (self.alloc + bytes_ - 1) & ~(bytes_ - 1)
        pa = self.alloc
        self.alloc += bytes_
        return pa

    def map(self, root, va, pa, perms, x4=False, level=0):
        """Install a leaf at `level` (0=4K, 1=2M) in an Sv39/Sv39x4 table."""
        a = root
        for lvl in (2, 1, 0):
            idx = (va >> (12 + 9 * lvl)) & (0x7FF if (x4 and lvl == 2) else 0x1FF)
            ent = a + idx * 8
            if lvl == level:
                self.w64(ent, ((pa >> 12) << 10) | perms)
                return
            nxt = self.r64(ent)
            if nxt & 1:
                a = ((nxt >> 10) & ((1 << 44) - 1)) << 12
            else:
                t = self.alloc_page()
                self.w64(ent, ((t >> 12) << 10) | V)
                a = t

    def setup_two_stage(self):
        g_root = self.alloc_page(0x4000)
        self.m.csr['hgatp'] = (8 << 60) | (7 << VMID_SHIFT) | (g_root >> 12)
        for i in range(2048):  # eager GPA [RAM_BASE, +8M) -> host +16M
            gpa = RAM_BASE + i * 0x1000
            self.map(g_root, gpa, gpa + HOST_OFF, RWXADU, x4=True)
        vs_root_gpa = RAM_BASE + 0x20_0000
        self.m.csr['vsatp'] = (8 << 60) | (3 << ASID_SHIFT) | (vs_root_gpa >> 12)
        return vs_root_gpa

    def g_root(self):
        return (self.m.csr['hgatp'] & ((1 << 44) - 1)) << 12

    def map_vs(self, vs_root_gpa, gva, gpa, perms):
        """VS-stage mapping; the tables live in guest RAM (host = gpa+16M)."""
        a = vs_root_gpa
        for lvl in (2, 1, 0):
            idx = (gva >> (12 + 9 * lvl)) & 0x1FF
            ent_host = a + HOST_OFF + idx * 8
            if lvl == 0:
                self.w64(ent_host, ((gpa >> 12) << 10) | perms)
                return
            nxt = self.r64(ent_host)
            if nxt & 1:
                a = ((nxt >> 10) & ((1 << 44) - 1)) << 12
            else:
                self.gpa_alloc += 0x1000
                t = self.gpa_alloc
                self.w64(ent_host, ((t >> 12) << 10) | V)
                a = t

    def load_code(self, pa, src):
        ir, data, _ = assemble(src, pa)
        self.m.ir.update(ir)
        for addr, blob in data:
            off = addr - RAM_BASE
            self.m.ram[off:off + len(blob)] = blob

    def run_to_trap(self, n=50):
        for _ in range(n):
            before = len(self.traps)
            self.m.step()
            if len(self.traps) > before:
                return self.traps[-1]
        raise AssertionError(f"no trap in {n} steps, pc={self.m.pc:#x}")


def hs_at(src, prv=1):
    w = World()
    w.load_code(RAM_BASE, src)
    w.m.pc = RAM_BASE
    w.m.prv = prv
    return w


def enter_vs(w, pc):
    w.m.prv, w.m.virt, w.m.pc = 1, True, pc


CHECKS = []


def check(fn):
    CHECKS.append(fn)
    return fn


# ---------------- ecall / ebreak causes ----------------
@check
def ecall_cause_matrix():
    for prv, virt, cause in ((3, False, 11), (1, False, 9), (1, True, 10),
                             (0, False, 8), (0, True, 8)):
        w = hs_at("ecall\n", prv=prv)
        w.m.virt = virt
        c, tgt, tval, *_ = w.run_to_trap()
        assert (c, tgt, tval) == (cause, 'M', 0), (prv, virt, c, tgt)


# ---------------- HLV/HSV/HLVX privilege gates ----------------
def hlv_world():
    w = hs_at("li t0, 0x6000\n hlv.d t1, (t0)\n ebreak\n")
    vs_root = w.setup_two_stage()
    gpa = RAM_BASE + 0x12000
    w.map_vs(vs_root, 0x6000, gpa, RWXADU)
    w.w64(gpa + HOST_OFF, 0xfeed_beef_dead_cafe)
    w.m.csr['hstatus'] |= SPVP
    return w


@check
def hlv_reads_guest_data_from_hs():
    w = hlv_world()
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M'), (c, tgt)
    assert w.m.regs[6] == 0xfeed_beef_dead_cafe, hex(w.m.regs[6])


@check
def hsv_writes_guest_data_from_m():
    w = hs_at("li t0, 0x6000\n li t1, 0x1234\n hsv.w t1, (t0)\n ebreak\n", prv=3)
    vs_root = w.setup_two_stage()
    gpa = RAM_BASE + 0x12000
    w.map_vs(vs_root, 0x6000, gpa, RWXADU)
    w.m.csr['hstatus'] |= SPVP
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M'), (c, tgt)
    assert w.r64(gpa + HOST_OFF) & 0xFFFF_FFFF == 0x1234


@check
def hlv_from_vs_is_virtual_instruction():
    w = hlv_world()
    enter_vs(w, RAM_BASE)
    vs_root = RAM_BASE + 0x20_0000
    w.map_vs(vs_root, RAM_BASE, RAM_BASE, RWXAD)  # guest identity code map
    w.load_code(RAM_BASE + HOST_OFF, "li t0, 0x6000\n hlv.d t1, (t0)\n")
    c, tgt, tval, *_ = w.run_to_trap()
    raw_hlv_d = (0x36 << 25) | (5 << 15) | (4 << 12) | (6 << 7) | 0x73
    assert (c, tgt, tval) == (22, 'M', raw_hlv_d), (c, tgt, hex(tval))


@check
def hlv_from_user_gated_by_hstatus_hu():
    w = hlv_world()
    w.m.prv = 0
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (2, 'M'), (c, tgt)
    w = hlv_world()
    w.m.prv = 0
    w.m.csr['hstatus'] |= HU
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0xfeed_beef_dead_cafe


@check
def hlv_page_permission_fault():
    w = hs_at("li t0, 0x6000\n hlv.d t1, (t0)\n")
    vs_root = w.setup_two_stage()
    gpa = RAM_BASE + 0x12000
    w.map_vs(vs_root, 0x6000, gpa, V | W | A | D | U)  # no R
    w.m.csr['hstatus'] |= SPVP
    c, tgt, tval, gpa_r, gva, tinst = w.run_to_trap()
    assert (c, tgt, tval, gva) == (13, 'M', 0x6000, True), (c, tgt, hex(tval))
    # Stage-1 faults carry no transformed instruction (walker.rs
    # stage1_fault): mtinst must be 0 and mtval2 must stay clear.
    assert tinst == 0 and w.m.csr['mtinst'] == 0 and w.m.csr['mtval2'] == 0


@check
def hlvx_requires_execute_permission():
    # R-only page: plain HLV reads it, HLVX wants X and faults.
    for head, ok in (("hlv.w", True), ("hlvx.wu", False)):
        w = hs_at(f"li t0, 0x6000\n {head} t1, (t0)\n ebreak\n")
        vs_root = w.setup_two_stage()
        gpa = RAM_BASE + 0x12000
        w.map_vs(vs_root, 0x6000, gpa, V | R | A | U)
        w.w64(gpa + HOST_OFF, 0x55aa_1234)
        w.m.csr['hstatus'] |= SPVP
        c, tgt, *_ = w.run_to_trap()
        if ok:
            assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0x55aa_1234
        else:
            assert (c, tgt) == (13, 'M'), (head, c, tgt)


# ---------------- per-stage MXR rules (riscv_hyp_tests mxr_world) --------
def mxr_world(vs_perms, g_perms):
    w = hs_at("li t0, 0x7000\n hlv.d t1, (t0)\n ebreak\n")
    vs_root = w.setup_two_stage()
    gpa = RAM_BASE + 0x800_0000          # outside the eager window
    host_pa = RAM_BASE + 0x1F_0000
    w.map_vs(vs_root, 0x7000, gpa, vs_perms)
    w.map(w.g_root(), gpa, host_pa, g_perms, x4=True)
    w.w64(host_pa, 0x1122_3344_5566_7788)
    w.m.csr['hstatus'] |= SPVP
    return w


@check
def vsstatus_mxr_reads_stage1_execute_only():
    w = mxr_world(XO_AD_U, RWXADU)
    w.m.csr['vsstatus'] |= MXR
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0x1122_3344_5566_7788
    w = mxr_world(XO_AD_U, RWXADU)       # no MXR anywhere -> stage-1 fault
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (13, 'M'), (c, tgt)


@check
def vsstatus_mxr_does_not_apply_at_g_stage():
    w = mxr_world(RWXADU, XO_U)
    w.m.csr['vsstatus'] |= MXR
    c, tgt, tval, gpa_r, gva, _ = w.run_to_trap()
    assert (c, tgt, tval, gva) == (21, 'M', 0x7000, True), (c, tgt)
    assert w.m.csr['mtval2'] == (RAM_BASE + 0x800_0000) >> 2
    assert w.m.csr['mtval'] == 0x7000
    assert w.m.csr['mstatus'] & GVA


@check
def mstatus_mxr_reads_g_stage_execute_only():
    w = mxr_world(RWXADU, XO_U)
    w.m.csr['mstatus'] |= MXR
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0x1122_3344_5566_7788


@check
def hlvx_reads_execute_only_at_both_stages():
    w = mxr_world(XO_AD_U, XO_U)
    w.load_code(RAM_BASE, "li t0, 0x7000\n hlvx.wu t1, (t0)\n ebreak\n")
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M'), (c, tgt)
    assert w.m.regs[6] == 0x5566_7788, hex(w.m.regs[6])


# ---------------- tinst: transformed + pseudo-instruction ----------------
@check
def implicit_pte_read_uses_original_access_cause():
    # Broken vsatp root (G-unmapped): the implicit PTE read guest-faults
    # with the ORIGINAL access's cause and tinst = pseudo PTE read.
    bad_root = RAM_BASE + 0x900_0000
    for src, cause in (("li t0, 0x6000\n hlv.d t1, (t0)\n", 21),
                       ("li t0, 0x6000\n li t1, 9\n hsv.d t1, (t0)\n", 23)):
        w = hs_at(src)
        w.setup_two_stage()
        w.m.csr['vsatp'] = (8 << 60) | (bad_root >> 12)
        w.m.csr['hstatus'] |= SPVP
        c, tgt, tval, gpa_r, gva, tinst = w.run_to_trap()
        assert (c, tgt, tval, gva) == (cause, 'M', 0x6000, True), (c, tgt)
        assert tinst == TINST_PSEUDO_PTE_READ, hex(tinst)
        pte_gpa = bad_root + ((0x6000 >> 30) & 0x1FF) * 8
        assert w.m.csr['mtval2'] == pte_gpa >> 2
    # Fetch through the broken root: cause 20, same pseudo tinst.
    w = hs_at("nop\n")
    w.setup_two_stage()
    w.m.csr['vsatp'] = (8 << 60) | (bad_root >> 12)
    enter_vs(w, 0x4000)
    c, tgt, tval, gpa_r, gva, tinst = w.run_to_trap()
    assert (c, tgt, tval, gva) == (20, 'M', 0x4000, True), (c, tgt)
    assert tinst == TINST_PSEUDO_PTE_READ


@check
def explicit_guest_fault_tinst_transformed_and_fetch_zero():
    # Explicit hlv.d to a G-unmapped leaf: transformed tinst.
    w = hs_at("li t0, 0x6000\n hlv.d t1, (t0)\n")
    vs_root = w.setup_two_stage()
    gpa = RAM_BASE + 0x800_0000
    w.map_vs(vs_root, 0x6000, gpa, RWXADU)
    w.m.csr['hstatus'] |= SPVP
    c, tgt, tval, gpa_r, gva, tinst = w.run_to_trap()
    raw = (0x36 << 25) | (5 << 15) | (4 << 12) | (6 << 7) | 0x73
    assert (c, tval, tinst) == (21, 0x6000, raw & ~(0x1F << 15)), (c, hex(tinst))
    assert w.m.csr['mtval2'] == gpa >> 2
    # Guest fetch of a G-unmapped GPA (vsatp off): cause 20, tinst = 0.
    w = hs_at("nop\n")
    w.setup_two_stage()
    w.m.csr['vsatp'] = 0
    enter_vs(w, RAM_BASE + 0x800_0000)
    c, tgt, tval, gpa_r, gva, tinst = w.run_to_trap()
    assert (c, tval, tinst, gva) == (20, RAM_BASE + 0x800_0000, 0, True)


# ---------------- WFI / SRET / HFENCE legality matrices ----------------
@check
def wfi_legality_matrix():
    for prv, virt, hst, mst, expect in (
            (3, False, 0, 0, None),            # M: executes
            (1, False, 0, 0, None),            # HS: executes
            (1, True, VTW, 0, 22),             # VS + VTW: virtual
            (1, False, 0, TW, 2),              # HS + TW: illegal
            (1, True, 0, TW, 2),               # TW beats VTW
            (0, True, 0, 0, 22),               # VU: virtual
            (0, False, 0, TW, 2)):             # U + TW: illegal
        w = hs_at("wfi\n ebreak\n", prv=prv)
        w.m.virt = virt
        w.m.csr['hstatus'] |= hst
        w.m.csr['mstatus'] |= mst
        c, tgt, tval, *_ = w.run_to_trap()
        want = 3 if expect is None else expect
        assert c == want, (prv, virt, hst, mst, c)
        if expect is not None:
            assert tval == 0x1050_0073, hex(tval)


@check
def virtual_instruction_group():
    vs_root_src = "csrr t0, hstatus\n"
    cases = (
        ("sret\n", VTSR, 0x1020_0073),
        ("sfence.vma\n", VTVM, (0x09 << 25) | 0x73),
        ("csrw satp, t0\n", VTVM, (0x180 << 20) | (5 << 15) | (1 << 12) | 0x73),
        (vs_root_src, 0, (0x600 << 20) | (2 << 12) | (5 << 7) | 0x73),
        ("hfence.vvma\n", 0, (0x11 << 25) | 0x73),
        ("hfence.gvma\n", 0, (0x31 << 25) | 0x73),
    )
    for src, hst, raw in cases:
        w = hs_at(src)
        w.m.virt = True
        w.m.csr['hstatus'] |= hst
        c, tgt, tval, *_ = w.run_to_trap()
        assert (c, tgt, tval) == (22, 'M', raw), (src, c, hex(tval), hex(raw))


@check
def hfence_from_u_is_illegal():
    for virt, cause in ((False, 2), (True, 22)):
        w = hs_at("hfence.gvma\n", prv=0)
        w.m.virt = virt
        c, tgt, *_ = w.run_to_trap()
        assert c == cause, (virt, c)


@check
def sret_tsr_and_satp_tvm_are_illegal_from_hs():
    for src, mst, cause in (("sret\n", TSR, 2),
                            ("csrw satp, t0\n", TVM, 2),
                            ("hfence.gvma\n", TVM, 2)):
        w = hs_at(src)
        w.m.csr['mstatus'] |= mst
        c, tgt, *_ = w.run_to_trap()
        assert c == cause, (src, c)


# ---------------- xip alias views ----------------
@check
def vsip_shifted_view_needs_delegation():
    for hideleg, expect in ((VSSIP, 1 << 1), (0, 0)):
        w = hs_at("csrr t0, sip\n ebreak\n")
        w.m.csr['hideleg'] = hideleg
        w.m.csr['mip'] = VSSIP
        w.m.virt = True
        c, tgt, *_ = w.run_to_trap()
        assert (c, tgt) == (3, 'M')
        assert w.m.regs[5] == expect, (hideleg, hex(w.m.regs[5]))


@check
def mideleg_reads_forced_vs_bits():
    w = hs_at("csrr t0, mideleg\n ebreak\n", prv=3)
    w.run_to_trap()
    assert w.m.regs[5] == VS_MASK_I | SGEIP, hex(w.m.regs[5])


# ---------------- two-stage translation + trap CSR writes ----------------
@check
def successful_two_stage_load_and_megapage():
    w = World()
    vs_root = w.setup_two_stage()
    code_gpa = RAM_BASE + 0x10000
    w.map_vs(vs_root, 0x4000, code_gpa, RWXAD)
    w.map_vs(vs_root, 0x6000, RAM_BASE + 0x12000, RWXAD)
    w.w64(RAM_BASE + 0x12000 + HOST_OFF, 0xabcd_ef01)
    w.load_code(code_gpa + HOST_OFF,
                "li t0, 0x6000\n ld t1, (t0)\n ebreak\n")
    enter_vs(w, 0x4000)
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0xabcd_ef01
    # 2M megapage VS leaf over the same data.
    w = World()
    vs_root = w.setup_two_stage()
    code_gpa = RAM_BASE + 0x10000
    w.map_vs(vs_root, 0x4000, code_gpa, RWXAD)
    # VA 0x20_0000 shares level-2 slot 0 with the code map: install a 2M
    # leaf at level 1 covering gpa [RAM_BASE, +2M).
    nxt = w.r64(vs_root + HOST_OFF)      # level-2 entry 0 (pointer)
    assert nxt & 1
    table = ((nxt >> 10) & ((1 << 44) - 1)) << 12
    idx1 = (0x20_0000 >> 21) & 0x1FF
    w.w64(table + HOST_OFF + idx1 * 8, ((RAM_BASE >> 12) << 10) | RWXAD)
    w.w64(RAM_BASE + 0x3_4568 + HOST_OFF, 0x77)
    w.load_code(code_gpa + HOST_OFF,
                "li t0, 0x00234568\n ld t1, (t0)\n ebreak\n")
    enter_vs(w, 0x4000)
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M') and w.m.regs[6] == 0x77, hex(w.m.regs[6])


@check
def vs_stage_fault_delegated_to_hs_sets_spv_spvp():
    w = World()
    w.m.csr['medeleg'] = 1 << 13
    w.m.csr['stvec'] = TRAMPOLINE
    vs_root = w.setup_two_stage()
    code_gpa = RAM_BASE + 0x10000
    w.map_vs(vs_root, 0x4000, code_gpa, RWXAD)
    w.load_code(code_gpa + HOST_OFF, "li t0, 0x6000\n ld t1, (t0)\n")
    enter_vs(w, 0x4000)
    c, tgt, tval, gpa_r, gva, _ = w.run_to_trap()
    assert (c, tgt, tval, gva) == (13, 'HS', 0x6000, True), (c, tgt)
    hs = w.m.csr['hstatus']
    assert hs & SPV and hs & SPVP and hs & H_GVA
    assert w.m.csr['htval'] == 0          # stage-1 fault: no GPA
    assert w.m.csr['scause'] == 13 and w.m.csr['stval'] == 0x6000
    assert not w.m.virt and w.m.prv == 1
    # sret returns to VS at sepc.
    w.load_code(TRAMPOLINE, "sret\n")
    w.m.step()
    assert w.m.virt and w.m.prv == 1 and w.m.pc == 0x4004


@check
def mret_with_mpv_enters_vs_and_clears_mprv():
    w = hs_at("nop\n", prv=3)
    w.setup_two_stage()
    w.m.csr['vsatp'] = 0
    vs_pc = RAM_BASE + 0x10000
    w.load_code(vs_pc + HOST_OFF, "ebreak\n")
    w.m.csr['mstatus'] |= MPV | MPRV | (1 << MPP_SHIFT)
    w.m.csr['mepc'] = vs_pc
    w.load_code(RAM_BASE, "mret\n")
    w.m.step()
    assert w.m.virt and w.m.prv == 1 and w.m.pc == vs_pc
    assert not w.m.csr['mstatus'] & MPRV and not w.m.csr['mstatus'] & MPV
    c, tgt, *_ = w.run_to_trap()
    assert (c, tgt) == (3, 'M')
    assert w.m.csr['mstatus'] & MPV       # trap from V=1 re-sets MPV
    assert w.m.csr['mepc'] == vs_pc


@check
def g_stage_only_fault_reports_gpa():
    w = hs_at("nop\n", prv=3)
    w.setup_two_stage()
    w.m.csr['vsatp'] = 0
    probe = RAM_BASE + 0x10000
    w.load_code(probe + HOST_OFF,
                "li t0, 0x88800000\n ld t1, (t0)\n")
    enter_vs(w, probe)
    c, tgt, tval, gpa_r, gva, _ = w.run_to_trap()
    assert (c, tgt, tval, gva) == (21, 'M', 0x8880_0000, True), (c, tgt)
    assert gpa_r == 0x8880_0000 and w.m.csr['mtval2'] == 0x8880_0000 >> 2
    assert w.m.csr['mstatus'] & GVA and w.m.csr['mstatus'] & MPV


# ---------------- CSR file model ----------------
@check
def csr_inventory_reads_from_m():
    names = [n for n in CSR_ADDR
             if n not in ('cycle', 'time', 'instret', 'mcycle', 'minstret',
                          'fflags', 'frm', 'fcsr')]
    src = "".join(f"csrr t0, {n}\n" for n in names) + "ebreak\n"
    w = hs_at(src, prv=3)
    c, tgt, *_ = w.run_to_trap(n=len(names) + 5)
    assert (c, tgt) == (3, 'M'), (c, tgt)


@check
def csr_min_priv_and_readonly():
    # hstatus from HS ok; from U illegal; hgeip writable never.
    w = hs_at("csrr t0, hstatus\n ebreak\n")
    assert w.run_to_trap()[0] == 3
    w = hs_at("csrr t0, hstatus\n", prv=0)
    assert w.run_to_trap()[0] == 2
    w = hs_at("csrw hgeip, t0\n", prv=3)
    assert w.run_to_trap()[0] == 2
    # csrs with rs1=x0 never writes: allowed on read-only CSRs.
    w = hs_at("csrs hgeip, x0\n ebreak\n", prv=3)
    assert w.run_to_trap()[0] == 3


@check
def guest_csr_redirection():
    w = hs_at("li t0, 0x1800\n csrw sscratch, t0\n csrr t1, sscratch\n ebreak\n")
    w.m.virt = True
    c, *_ = w.run_to_trap()
    assert c == 3
    assert w.m.csr['vsscratch'] == 0x1800 and w.m.csr['sscratch'] == 0
    assert w.m.regs[6] == 0x1800


def main():
    failed = 0
    for fn in CHECKS:
        try:
            fn()
            print(f"{fn.__name__:<50} ok")
        except AssertionError as e:
            failed += 1
            print(f"{fn.__name__:<50} FAIL {e}")
    if failed:
        sys.exit(f"{failed}/{len(CHECKS)} emu-hyp cross-checks FAILED")
    print(f"ALL {len(CHECKS)} EMU-HYP CROSS-CHECKS PASSED")


if __name__ == "__main__":
    main()
